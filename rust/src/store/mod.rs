//! In-memory key-value store — the Redis substitute (paper §2.3, §2.7).
//!
//! Implements the Redis semantics the paper relies on:
//!
//! * **in-memory hash storage** with O(1) get/set;
//! * **per-entry TTL** with both lazy expiry (on access) and an active
//!   sweeper (`sweep_expired`, driven by the coordinator's housekeeping
//!   thread — Redis' `activeExpireCycle` analogue);
//! * **bounded memory**: a legacy count capacity with lazy-LRU eviction
//!   (Redis `allkeys-lru`), plus byte-accurate weight tracking — each
//!   entry can carry a byte footprint and a latency cost, the store
//!   keeps an exact byte ledger ([`KvStore::bytes`], mirrored into any
//!   shared [`StoreConfig::ledgers`] counters), and a pluggable
//!   [`crate::eviction::EvictionPolicy`] picks byte-budget victims via
//!   [`KvStore::victim`] / [`KvStore::evict`] (the budget itself is
//!   enforced by the cache layer, across partitions);
//! * **read-mostly `RwLock` sharding** to keep lock contention off the
//!   request path: when the store is unbounded *and* untracked (no LRU
//!   or frequency bookkeeping), concurrent `get`s on one shard take only
//!   the shared lock and proceed in parallel; writers and tracked reads
//!   take the exclusive lock;
//! * hit/miss/expiry/eviction **stats** (Redis `INFO` analogue).
//!
//! The store is deliberately type-parameterized (`KvStore<V>`): the
//! semantic cache stores full entries (question + response + embedding)
//! while tests exercise it with small values.

mod clock;
mod shard;

pub use clock::{Clock, ManualClock, SystemClock};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::sync::RwLock;

use crate::eviction::EvictionPolicy;
use shard::Shard;

/// Store-wide statistics (monotonic counters).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub expired: AtomicU64,
    pub evicted: AtomicU64,
    pub inserts: AtomicU64,
}

/// Point-in-time snapshot of [`StoreStats`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub expired: u64,
    pub evicted: u64,
    pub inserts: u64,
    pub len: usize,
}

/// Configuration for a [`KvStore`].
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Number of shards (power of two recommended).
    pub shards: usize,
    /// Maximum number of live entries across all shards; 0 = unbounded.
    /// Legacy count bound — byte budgets live at the cache layer.
    pub capacity: usize,
    /// Default TTL in milliseconds applied by [`KvStore::set`]; 0 = no expiry.
    pub default_ttl_ms: u64,
    /// Maintain recency/frequency metadata on reads even when the store
    /// is count-unbounded. Required for byte-budget eviction scoring;
    /// costs the shared-lock read fast path.
    pub track_access: bool,
    /// External byte counters mirrored by the store's ledger — the cache
    /// layer threads its global and per-tenant byte ledgers through here
    /// so every weighted mutation (insert/overwrite/remove/expiry/evict)
    /// updates them exactly once.
    pub ledgers: Vec<Arc<AtomicU64>>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            shards: 16,
            capacity: 0,
            default_ttl_ms: 0,
            track_access: false,
            ledgers: Vec::new(),
        }
    }
}

/// A byte-budget eviction candidate ([`KvStore::victim`]).
#[derive(Debug, Clone)]
pub struct StoreVictim {
    pub key: String,
    /// Policy score; lower = evict first (expired residents are
    /// negative infinity).
    pub score: f64,
    /// Last-access stamp (tie-break: colder loses).
    pub seq: u64,
    /// Footprint the eviction would free.
    pub bytes: u64,
}

/// Sharded TTL+LRU key-value store with byte-accurate weight tracking.
pub struct KvStore<V> {
    shards: Vec<RwLock<Shard<V>>>,
    stats: StoreStats,
    clock: Arc<dyn Clock>,
    per_shard_capacity: usize,
    default_ttl_ms: u64,
    track_access: bool,
    /// Exact bytes resident in this store (weighted entries only).
    bytes: AtomicU64,
    /// Store-wide access stamp source: one counter across shards, so
    /// recency comparisons in the victim scan are meaningful globally.
    seq: AtomicU64,
    ledgers: Vec<Arc<AtomicU64>>,
}

impl<V> KvStore<V> {
    pub fn new(cfg: StoreConfig) -> Self {
        Self::with_clock(cfg, Arc::new(SystemClock))
    }

    /// Inject a clock — tests drive TTL expiry with [`ManualClock`].
    pub fn with_clock(cfg: StoreConfig, clock: Arc<dyn Clock>) -> Self {
        let shards = cfg.shards.max(1);
        // Capacity is enforced per shard; round up so total >= requested.
        let per_shard_capacity =
            if cfg.capacity == 0 { 0 } else { cfg.capacity.div_ceil(shards) };
        Self {
            shards: (0..shards).map(|_| RwLock::new(Shard::new())).collect(),
            stats: StoreStats::default(),
            clock,
            per_shard_capacity,
            default_ttl_ms: cfg.default_ttl_ms,
            track_access: cfg.track_access,
            bytes: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            ledgers: cfg.ledgers,
        }
    }

    fn shard_for(&self, key: &str) -> &RwLock<Shard<V>> {
        let h = crate::tokenizer::fnv1a64(key.as_bytes());
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn charge(&self, added: u64, freed: u64) {
        if added == freed {
            return;
        }
        if added > freed {
            let n = added - freed;
            self.bytes.fetch_add(n, Ordering::Relaxed);
            for l in &self.ledgers {
                l.fetch_add(n, Ordering::Relaxed);
            }
        } else {
            let n = freed - added;
            self.bytes.fetch_sub(n, Ordering::Relaxed);
            for l in &self.ledgers {
                l.fetch_sub(n, Ordering::Relaxed);
            }
        }
    }

    /// Bytes currently resident (sum of weighted entries' footprints,
    /// including expired-but-not-yet-reclaimed ones).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Insert with the default TTL.
    pub fn set(&self, key: &str, value: V) {
        self.set_ttl(key, value, self.default_ttl_ms);
    }

    /// Insert with an explicit TTL (ms); 0 = never expires.
    pub fn set_ttl(&self, key: &str, value: V, ttl_ms: u64) {
        self.set_ttl_weighted(key, value, ttl_ms, 0, 0.0);
    }

    /// Insert with an explicit TTL, byte footprint, and latency cost.
    /// The footprint is charged to the store ledger (and any shared
    /// ledgers) immediately; count-capacity evictions triggered by the
    /// insert are returned as keys so the caller can reclaim secondary
    /// structures keyed on the same entries.
    pub fn set_ttl_weighted(
        &self,
        key: &str,
        value: V,
        ttl_ms: u64,
        bytes: u64,
        cost_ms: f64,
    ) -> Vec<String> {
        let now = self.clock.now_ms();
        let expires = if ttl_ms == 0 { u64::MAX } else { now + ttl_ms };
        let seq = self.next_seq();
        let (evicted, freed) = self.shard_for(key).write().unwrap().insert(
            key.to_string(),
            value,
            expires,
            self.per_shard_capacity,
            seq,
            bytes,
            cost_ms,
        );
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        self.stats.evicted.fetch_add(evicted.len() as u64, Ordering::Relaxed);
        self.charge(bytes, freed);
        evicted
    }
}

impl<V: Clone> KvStore<V> {
    /// Get a clone of the live value; lazily expires dead entries.
    ///
    /// Read-mostly fast path: when the store is unbounded (capacity 0)
    /// and not access-tracked there is no recency or frequency state to
    /// maintain, so a hit only takes the shard's *shared* lock —
    /// concurrent readers of one shard proceed in parallel. The
    /// exclusive lock is taken only to reclaim an entry that was
    /// observed expired (idempotent under races) or, in the bounded /
    /// tracked configurations, to bump recency and frequency.
    pub fn get(&self, key: &str) -> Option<V> {
        let now = self.clock.now_ms();
        let lock = self.shard_for(key);
        if self.per_shard_capacity == 0 && !self.track_access {
            let shard = lock.read().unwrap();
            match shard.peek(key, now) {
                shard::Lookup::Hit(v) => {
                    let v = v.clone();
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(v);
                }
                shard::Lookup::Miss => {
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                shard::Lookup::Expired => {}
            }
            drop(shard);
            // Upgrade to reclaim the expired entry; another thread may have
            // raced us (re-inserted or already reclaimed), so re-check.
            if let Some(freed) = lock.write().unwrap().remove_expired(key, now) {
                self.stats.expired.fetch_add(1, Ordering::Relaxed);
                self.charge(0, freed);
            }
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let seq = self.next_seq();
        let (out, freed) = {
            let mut shard = lock.write().unwrap();
            let (lookup, freed) = shard.get(key, now, seq);
            let out = match lookup {
                shard::Lookup::Hit(v) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    Some(v.clone())
                }
                shard::Lookup::Expired => {
                    self.stats.expired.fetch_add(1, Ordering::Relaxed);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
                shard::Lookup::Miss => {
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
            };
            (out, freed)
        };
        self.charge(0, freed);
        out
    }
}

impl<V> KvStore<V> {
    /// Remove a key; true if it was present and live.
    pub fn remove(&self, key: &str) -> bool {
        let now = self.clock.now_ms();
        let (was_live, freed) = self.shard_for(key).write().unwrap().remove(key, now);
        self.charge(0, freed);
        was_live
    }

    /// Byte-budget eviction: remove `key` unconditionally, releasing its
    /// footprint. Returns the freed bytes if the key was resident.
    pub fn evict(&self, key: &str) -> Option<u64> {
        let freed = self.shard_for(key).write().unwrap().evict(key)?;
        self.stats.evicted.fetch_add(1, Ordering::Relaxed);
        self.charge(0, freed);
        Some(freed)
    }

    /// The store-wide lowest-scoring entry under `policy` (the byte
    /// budget's next victim): min over each shard's scan, tie-broken by
    /// the colder access stamp. O(n) in resident entries.
    pub fn victim(&self, policy: &dyn EvictionPolicy) -> Option<StoreVictim> {
        let now = self.clock.now_ms();
        let mut best: Option<StoreVictim> = None;
        for shard in &self.shards {
            if let Some(v) = shard.read().unwrap().victim(policy, now) {
                let better = match &best {
                    None => true,
                    Some(b) => v.score < b.score || (v.score == b.score && v.seq < b.seq),
                };
                if better {
                    best = Some(StoreVictim {
                        key: v.key,
                        score: v.score,
                        seq: v.seq,
                        bytes: v.bytes,
                    });
                }
            }
        }
        best
    }

    /// Remaining TTL in ms (None = missing/expired; u64::MAX = immortal).
    pub fn ttl_ms(&self, key: &str) -> Option<u64> {
        let now = self.clock.now_ms();
        let shard = self.shard_for(key).read().unwrap();
        shard.ttl_remaining(key, now)
    }

    /// Active expiry cycle: drop every expired entry, returning the count.
    /// The coordinator's housekeeping thread calls this periodically.
    pub fn sweep_expired(&self) -> usize {
        let now = self.clock.now_ms();
        let mut total = 0;
        let mut freed = 0;
        for shard in &self.shards {
            let (n, f) = shard.write().unwrap().sweep(now);
            total += n;
            freed += f;
        }
        self.stats.expired.fetch_add(total as u64, Ordering::Relaxed);
        self.charge(0, freed);
        total
    }

    /// Active expiry cycle that returns the swept keys, so callers keeping
    /// secondary structures keyed on the same entries (the cache
    /// partition's vector index + embedding map) can reclaim in lockstep.
    pub fn sweep_expired_keys(&self) -> Vec<String> {
        let now = self.clock.now_ms();
        let mut keys = Vec::new();
        let mut freed = 0;
        for shard in &self.shards {
            freed += shard.write().unwrap().sweep_keys(now, &mut keys);
        }
        self.stats.expired.fetch_add(keys.len() as u64, Ordering::Relaxed);
        self.charge(0, freed);
        keys
    }

    /// Live entry count (does not count not-yet-swept expired entries).
    pub fn len(&self) -> usize {
        let now = self.clock.now_ms();
        self.shards.iter().map(|s| s.read().unwrap().live_len(now)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every live entry (used by snapshot/rebuild paths).
    pub fn for_each<F: FnMut(&str, &V)>(&self, mut f: F) {
        let now = self.clock.now_ms();
        for shard in &self.shards {
            shard.read().unwrap().for_each_live(now, &mut f);
        }
    }

    /// Visit every live entry with its absolute expiry on this store's
    /// clock (u64::MAX = immortal). Snapshot dumps use this to convert
    /// monotonic expiries into wall-clock expiries that survive restarts.
    pub fn for_each_with_expiry<F: FnMut(&str, &V, u64)>(&self, mut f: F) {
        let now = self.clock.now_ms();
        for shard in &self.shards {
            shard.read().unwrap().for_each_live_expiry(now, &mut f);
        }
    }

    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            evicted: self.stats.evicted.load(Ordering::Relaxed),
            inserts: self.stats.inserts.load(Ordering::Relaxed),
            len: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual_store(capacity: usize, ttl: u64) -> (KvStore<String>, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new(1_000));
        let cfg = StoreConfig { shards: 4, capacity, default_ttl_ms: ttl, ..Default::default() };
        (KvStore::with_clock(cfg, clock.clone()), clock)
    }

    #[test]
    fn set_get_remove() {
        let (s, _) = manual_store(0, 0);
        assert_eq!(s.get("a"), None);
        s.set("a", "1".into());
        assert_eq!(s.get("a"), Some("1".into()));
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.get("a"), None);
    }

    #[test]
    fn overwrite_updates_value_and_ttl() {
        let (s, clock) = manual_store(0, 0);
        s.set_ttl("k", "v1".into(), 100);
        s.set_ttl("k", "v2".into(), 10_000);
        clock.advance(5_000);
        assert_eq!(s.get("k"), Some("v2".into()));
    }

    #[test]
    fn ttl_lazy_expiry() {
        let (s, clock) = manual_store(0, 500);
        s.set("k", "v".into());
        assert_eq!(s.get("k"), Some("v".into()));
        clock.advance(499);
        assert_eq!(s.get("k"), Some("v".into()));
        clock.advance(2);
        assert_eq!(s.get("k"), None);
        let st = s.stats();
        assert_eq!(st.expired, 1);
        assert_eq!(st.hits, 2);
        assert_eq!(st.misses, 1);
    }

    #[test]
    fn ttl_zero_is_immortal() {
        let (s, clock) = manual_store(0, 0);
        s.set("k", "v".into());
        clock.advance(u64::MAX / 4);
        assert_eq!(s.get("k"), Some("v".into()));
        assert_eq!(s.ttl_ms("k"), Some(u64::MAX));
    }

    #[test]
    fn active_sweep_counts_and_removes() {
        let (s, clock) = manual_store(0, 100);
        for i in 0..50 {
            s.set(&format!("k{i}"), "v".into());
        }
        s.set_ttl("keep", "v".into(), 0);
        clock.advance(200);
        let swept = s.sweep_expired();
        assert_eq!(swept, 50);
        assert_eq!(s.len(), 1);
        assert_eq!(s.sweep_expired(), 0);
    }

    #[test]
    fn sweep_expired_keys_reports_what_it_removed() {
        let (s, clock) = manual_store(0, 100);
        s.set("gone1", "x".into());
        s.set("gone2", "x".into());
        s.set_ttl("keep", "y".into(), 0);
        clock.advance(200);
        let mut keys = s.sweep_expired_keys();
        keys.sort();
        assert_eq!(keys, vec!["gone1".to_string(), "gone2".to_string()]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().expired, 2);
        assert!(s.sweep_expired_keys().is_empty());
    }

    #[test]
    fn for_each_with_expiry_exposes_absolute_expiry() {
        let (s, _clock) = manual_store(0, 0);
        s.set_ttl("immortal", "a".into(), 0);
        s.set_ttl("mortal", "b".into(), 500);
        let mut seen = Vec::new();
        s.for_each_with_expiry(|k, _, exp| seen.push((k.to_string(), exp)));
        seen.sort();
        assert_eq!(seen[0], ("immortal".to_string(), u64::MAX));
        assert_eq!(seen[1], ("mortal".to_string(), 1_500)); // clock starts at 1_000
    }

    #[test]
    fn lru_eviction_prefers_cold_keys() {
        let clock = Arc::new(ManualClock::new(0));
        // Single shard so capacity semantics are exact.
        let cfg = StoreConfig { shards: 1, capacity: 3, default_ttl_ms: 0, ..Default::default() };
        let s: KvStore<String> = KvStore::with_clock(cfg, clock);
        s.set("a", "1".into());
        s.set("b", "2".into());
        s.set("c", "3".into());
        // Touch a and c so b is coldest.
        assert!(s.get("a").is_some());
        assert!(s.get("c").is_some());
        s.set("d", "4".into());
        assert_eq!(s.get("b"), None, "cold key evicted");
        assert!(s.get("a").is_some());
        assert!(s.get("c").is_some());
        assert!(s.get("d").is_some());
        assert_eq!(s.stats().evicted, 1);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn byte_ledger_tracks_every_mutation() {
        let shared = Arc::new(AtomicU64::new(0));
        let clock = Arc::new(ManualClock::new(0));
        let cfg = StoreConfig {
            shards: 2,
            track_access: true,
            ledgers: vec![shared.clone()],
            ..Default::default()
        };
        let s: KvStore<String> = KvStore::with_clock(cfg, clock.clone());
        s.set_ttl_weighted("a", "v".into(), 0, 100, 1.0);
        s.set_ttl_weighted("b", "v".into(), 50, 200, 1.0);
        assert_eq!(s.bytes(), 300);
        assert_eq!(shared.load(Ordering::Relaxed), 300, "shared ledger mirrors the store");
        // Overwrite releases the old footprint.
        s.set_ttl_weighted("a", "v2".into(), 0, 150, 1.0);
        assert_eq!(s.bytes(), 350);
        // Expiry (via sweep) releases bytes.
        clock.advance(100);
        assert_eq!(s.sweep_expired(), 1);
        assert_eq!(s.bytes(), 150);
        // Removal releases bytes.
        assert!(s.remove("a"));
        assert_eq!(s.bytes(), 0);
        assert_eq!(shared.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn victim_and_evict_follow_the_policy() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = StoreConfig { shards: 2, track_access: true, ..Default::default() };
        let s: KvStore<String> = KvStore::with_clock(cfg, clock);
        s.set_ttl_weighted("cheap", "v".into(), 0, 100, 10.0);
        s.set_ttl_weighted("pricey", "v".into(), 0, 100, 9_000.0);
        // LRU: "cheap" was inserted first and never touched again.
        let v = s.victim(&crate::eviction::Lru).unwrap();
        assert_eq!(v.key, "cheap");
        // Touch "cheap" so it is the recency winner; LRU flips…
        assert!(s.get("cheap").is_some());
        assert_eq!(s.victim(&crate::eviction::Lru).unwrap().key, "pricey");
        // …but cost-aware still sacrifices the low-value entry.
        assert_eq!(s.victim(&crate::eviction::CostAware).unwrap().key, "cheap");
        assert_eq!(s.evict("cheap"), Some(100));
        assert_eq!(s.evict("cheap"), None, "second eviction is a no-op");
        assert_eq!(s.bytes(), 100);
        assert_eq!(s.stats().evicted, 1);
    }

    #[test]
    fn tracked_reads_bump_frequency_for_lfu() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = StoreConfig { shards: 1, track_access: true, ..Default::default() };
        let s: KvStore<String> = KvStore::with_clock(cfg, clock);
        s.set_ttl_weighted("rare", "v".into(), 0, 10, 0.0);
        s.set_ttl_weighted("popular", "v".into(), 0, 10, 0.0);
        for _ in 0..5 {
            assert!(s.get("popular").is_some());
        }
        // "rare" was accessed once (the insert), "popular" six times.
        assert_eq!(s.victim(&crate::eviction::Lfu).unwrap().key, "rare");
    }

    #[test]
    fn len_ignores_expired() {
        let (s, clock) = manual_store(0, 100);
        s.set("a", "x".into());
        s.set_ttl("b", "y".into(), 0);
        assert_eq!(s.len(), 2);
        clock.advance(150);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn for_each_visits_live_only() {
        let (s, clock) = manual_store(0, 100);
        s.set("dead", "x".into());
        s.set_ttl("live", "y".into(), 1_000);
        clock.advance(150);
        let mut seen = Vec::new();
        s.for_each(|k, _| seen.push(k.to_string()));
        assert_eq!(seen, vec!["live"]);
    }

    #[test]
    fn concurrent_readers_share_the_fast_path() {
        // Unbounded untracked store: parallel gets take only the shared
        // lock; all of them must see consistent values and stats.
        let s: Arc<KvStore<String>> = Arc::new(KvStore::new(StoreConfig {
            shards: 2,
            capacity: 0,
            default_ttl_ms: 0,
            ..Default::default()
        }));
        for i in 0..64 {
            s.set(&format!("k{i}"), format!("v{i}"));
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..200usize {
                    let i = round % 64;
                    assert_eq!(s.get(&format!("k{i}")), Some(format!("v{i}")));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().hits, 4 * 200);
        assert_eq!(s.stats().misses, 0);
    }

    #[test]
    fn concurrent_smoke() {
        use std::sync::Arc as A;
        let s: A<KvStore<u64>> = A::new(KvStore::new(StoreConfig {
            shards: 8,
            capacity: 0,
            default_ttl_ms: 0,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    let k = format!("k{}", (t * 1000 + i) % 256);
                    s.set(&k, i);
                    let _ = s.get(&k);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 256);
    }
}
