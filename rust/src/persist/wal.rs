//! Append-only write-ahead log: checksummed, length-prefixed records of
//! every cache mutation, stored in numbered segment files.
//!
//! Segment layout: an 8-byte magic (`SCWAL002`; v2 added the tenant
//! namespace and latency cost to each record, plus the `Evict` op — a
//! v1 log from an older build fails the magic check and recovery starts
//! cold rather than mis-decoding) followed by records of
//! the form `[u32 payload_len][u32 crc32(payload)][payload]`. A crash can
//! tear the tail of the newest segment mid-record; the reader treats any
//! short, oversized, or checksum-failing record as end-of-log and returns
//! the valid prefix — a torn tail is *normal*, never an error.
//!
//! Segments rotate at snapshot time: the snapshot records the sequence
//! number of the first segment it does *not* cover, and older segments
//! are deleted once the snapshot is durable.

use std::fs::{self, File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};

use crate::cache::CachedEntry;

use super::codec::{self, DecodeResult, Reader};

/// Segment file header.
pub const WAL_MAGIC: &[u8; 8] = b"SCWAL002";

/// Ceiling on a single record's payload (a flipped length byte must not
/// trigger a huge allocation; real records are a few KB).
const MAX_RECORD_BYTES: u32 = 1 << 28;

/// Fsync policy for WAL appends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalSync {
    /// `write(2)` only: the record is in the page cache before the
    /// request is acknowledged, which survives process crashes (SIGKILL)
    /// but not host power loss. The serving default.
    Os,
    /// `fsync` after every record: survives power loss, costs a disk
    /// flush per mutation.
    Always,
}

impl WalSync {
    pub fn parse(s: &str) -> Option<WalSync> {
        match s {
            "os" => Some(WalSync::Os),
            "always" => Some(WalSync::Always),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WalSync::Os => "os",
            WalSync::Always => "always",
        }
    }
}

/// One logged cache mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    Insert {
        tenant: String,
        dim: u32,
        id: u64,
        /// Absolute wall-clock expiry in ms (`u64::MAX` = immortal).
        expires_wall_ms: u64,
        cluster: u64,
        /// Upstream latency this entry saves per hit (the cost-aware
        /// eviction signal); stored as IEEE-754 bits.
        latency_ms: f64,
        question: String,
        response: String,
        embedding: Vec<f32>,
    },
    Remove {
        tenant: String,
        dim: u32,
        id: u64,
    },
    Clear,
    /// A capacity/byte-budget eviction. Replayed as a removal so a warm
    /// restart does not resurrect evicted entries from earlier Insert
    /// records in the same log.
    Evict {
        tenant: String,
        dim: u32,
        id: u64,
    },
}

const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const OP_CLEAR: u8 = 3;
const OP_EVICT: u8 = 4;

impl WalOp {
    pub fn insert(
        tenant: &str,
        dim: usize,
        id: u64,
        embedding: &[f32],
        entry: &CachedEntry,
        expires_wall_ms: u64,
    ) -> WalOp {
        WalOp::Insert {
            tenant: tenant.to_string(),
            dim: dim as u32,
            id,
            expires_wall_ms,
            cluster: entry.cluster,
            latency_ms: entry.latency_ms,
            question: entry.question.clone(),
            response: entry.response.clone(),
            embedding: embedding.to_vec(),
        }
    }

    fn encode_payload(&self, buf: &mut Vec<u8>) {
        match self {
            WalOp::Insert {
                tenant,
                dim,
                id,
                expires_wall_ms,
                cluster,
                latency_ms,
                question,
                response,
                embedding,
            } => {
                codec::put_u8(buf, OP_INSERT);
                codec::put_str(buf, tenant);
                codec::put_u32(buf, *dim);
                codec::put_u64(buf, *id);
                codec::put_u64(buf, *expires_wall_ms);
                codec::put_u64(buf, *cluster);
                codec::put_u64(buf, latency_ms.to_bits());
                codec::put_str(buf, question);
                codec::put_str(buf, response);
                codec::put_f32s(buf, embedding);
            }
            WalOp::Remove { tenant, dim, id } => {
                codec::put_u8(buf, OP_REMOVE);
                codec::put_str(buf, tenant);
                codec::put_u32(buf, *dim);
                codec::put_u64(buf, *id);
            }
            WalOp::Clear => codec::put_u8(buf, OP_CLEAR),
            WalOp::Evict { tenant, dim, id } => {
                codec::put_u8(buf, OP_EVICT);
                codec::put_str(buf, tenant);
                codec::put_u32(buf, *dim);
                codec::put_u64(buf, *id);
            }
        }
    }

    fn decode_payload(r: &mut Reader<'_>) -> DecodeResult<WalOp> {
        let op = match r.u8()? {
            OP_INSERT => WalOp::Insert {
                tenant: r.str()?,
                dim: r.u32()?,
                id: r.u64()?,
                expires_wall_ms: r.u64()?,
                cluster: r.u64()?,
                latency_ms: f64::from_bits(r.u64()?),
                question: r.str()?,
                response: r.str()?,
                embedding: r.f32s()?,
            },
            OP_REMOVE => WalOp::Remove { tenant: r.str()?, dim: r.u32()?, id: r.u64()? },
            OP_CLEAR => WalOp::Clear,
            OP_EVICT => WalOp::Evict { tenant: r.str()?, dim: r.u32()?, id: r.u64()? },
            other => {
                return Err(codec::DecodeError(format!("unknown wal op {other}")));
            }
        };
        if !r.is_empty() {
            return Err(codec::DecodeError("trailing bytes in wal payload".into()));
        }
        Ok(op)
    }
}

/// Encode one framed record: `[len][crc][payload]`.
pub fn encode_record(op: &WalOp) -> Vec<u8> {
    let mut payload = Vec::new();
    op.encode_payload(&mut payload);
    let mut rec = Vec::with_capacity(payload.len() + 8);
    codec::put_u32(&mut rec, payload.len() as u32);
    codec::put_u32(&mut rec, codec::crc32(&payload));
    rec.extend_from_slice(&payload);
    rec
}

/// Path of segment `seq` in `dir`.
pub fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:016}.log"))
}

/// All WAL segments in `dir`, sorted by ascending sequence number.
pub fn list_segments(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")) {
            if let Ok(seq) = num.parse::<u64>() {
                out.push((seq, entry.path()));
            }
        }
    }
    out.sort_by_key(|(seq, _)| *seq);
    Ok(out)
}

/// Truncate a segment to its valid prefix (as reported by
/// [`read_segment`]'s `valid_len`) and sync the result, sealing a torn
/// tail. After sealing, the segment scans clean — which is what lets
/// recovery keep *later* segments: a segment left torn on disk would be
/// re-detected as torn by every future recovery, each of which would
/// then discard the (acknowledged) history written after it.
pub fn truncate_segment(path: &Path, valid_len: u64) -> std::io::Result<()> {
    let file = OpenOptions::new().write(true).open(path)?;
    file.set_len(valid_len)?;
    file.sync_all()
}

/// Appender for one WAL segment.
pub struct WalWriter {
    file: File,
    seq: u64,
    sync: WalSync,
}

impl WalWriter {
    /// Create (truncate) segment `seq` and write its header.
    pub fn create(dir: &Path, seq: u64, sync: WalSync) -> std::io::Result<WalWriter> {
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(segment_path(dir, seq))?;
        file.write_all(WAL_MAGIC)?;
        if sync == WalSync::Always {
            file.sync_data()?;
        }
        Ok(WalWriter { file, seq, sync })
    }

    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Append one record; returns its framed length in bytes. The record
    /// is handed to the OS (and fsynced under [`WalSync::Always`]) before
    /// this returns, so a caller that acknowledges the mutation afterward
    /// never acknowledges something a SIGKILL can lose.
    pub fn append(&mut self, op: &WalOp) -> std::io::Result<u64> {
        let rec = encode_record(op);
        self.file.write_all(&rec)?;
        if self.sync == WalSync::Always {
            self.file.sync_data()?;
        }
        Ok(rec.len() as u64)
    }
}

/// Result of scanning one segment.
pub struct SegmentScan {
    pub ops: Vec<WalOp>,
    /// Byte length of the valid prefix (header + whole records).
    pub valid_len: u64,
    /// Whether bytes past the valid prefix were discarded (torn tail,
    /// bit rot, or a foreign file).
    pub torn: bool,
}

/// Read every valid record from the front of a segment. Decode failures
/// terminate the scan; they are reported via `torn`, never as errors —
/// only real I/O failures (open/read) error.
pub fn read_segment(path: &Path) -> std::io::Result<SegmentScan> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        return Ok(SegmentScan { ops: Vec::new(), valid_len: 0, torn: !bytes.is_empty() });
    }
    let mut ops = Vec::new();
    let mut pos = WAL_MAGIC.len();
    loop {
        let rest = &bytes[pos..];
        if rest.is_empty() {
            return Ok(SegmentScan { ops, valid_len: pos as u64, torn: false });
        }
        if rest.len() < 8 {
            break; // torn mid-header
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]);
        let crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
        if len > MAX_RECORD_BYTES || (len as usize) > rest.len() - 8 {
            break; // torn mid-payload or corrupt length
        }
        let payload = &rest[8..8 + len as usize];
        if codec::crc32(payload) != crc {
            break; // bit rot or torn overwrite
        }
        match WalOp::decode_payload(&mut Reader::new(payload)) {
            Ok(op) => ops.push(op),
            Err(_) => break, // checksum passed but payload malformed
        }
        pos += 8 + len as usize;
    }
    Ok(SegmentScan { ops, valid_len: pos as u64, torn: true })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "semcache-wal-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_ops() -> Vec<WalOp> {
        vec![
            WalOp::Insert {
                tenant: "default".into(),
                dim: 4,
                id: 1,
                expires_wall_ms: u64::MAX,
                cluster: 7,
                latency_ms: 812.5,
                question: "how do i reset my password".into(),
                response: "click forgot password".into(),
                embedding: vec![0.1, -0.2, 0.3, 0.4],
            },
            WalOp::Remove { tenant: "default".into(), dim: 4, id: 1 },
            WalOp::Clear,
            WalOp::Insert {
                tenant: "bot-7".into(),
                dim: 2,
                id: 9,
                expires_wall_ms: 123_456,
                cluster: 0,
                latency_ms: 0.0,
                question: "q".into(),
                response: String::new(),
                embedding: vec![1.0, 0.0],
            },
            WalOp::Evict { tenant: "bot-7".into(), dim: 2, id: 9 },
        ]
    }

    #[test]
    fn append_read_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut w = WalWriter::create(&dir, 3, WalSync::Os).unwrap();
        for op in &sample_ops() {
            w.append(op).unwrap();
        }
        drop(w);
        let scan = read_segment(&segment_path(&dir, 3)).unwrap();
        assert_eq!(scan.ops, sample_ops());
        assert!(!scan.torn);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_recovers_valid_prefix() {
        let dir = tmpdir("torn");
        let mut w = WalWriter::create(&dir, 0, WalSync::Os).unwrap();
        for op in &sample_ops() {
            w.append(op).unwrap();
        }
        drop(w);
        let path = segment_path(&dir, 0);
        let full = fs::read(&path).unwrap();
        // Cut the file at every possible length: the scan must never
        // panic and must recover a prefix of the written ops.
        let want = sample_ops();
        for cut in 0..full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            let scan = read_segment(&path).unwrap();
            assert!(scan.ops.len() <= want.len());
            assert_eq!(scan.ops[..], want[..scan.ops.len()], "cut={cut}");
            assert!(scan.valid_len <= cut as u64);
            if cut < full.len() {
                // Anything but the exact file is either torn or shorter.
                assert!(scan.torn || scan.ops.len() < want.len());
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bitflips_never_surface_a_bad_record() {
        let dir = tmpdir("flip");
        let mut w = WalWriter::create(&dir, 0, WalSync::Os).unwrap();
        let ops = sample_ops();
        for op in &ops {
            w.append(op).unwrap();
        }
        drop(w);
        let path = segment_path(&dir, 0);
        let full = fs::read(&path).unwrap();
        for byte in 0..full.len() {
            let mut corrupted = full.clone();
            corrupted[byte] ^= 0x40;
            fs::write(&path, &corrupted).unwrap();
            let scan = read_segment(&path).unwrap();
            // Every surfaced record must be one of the records actually
            // written (the flip may legitimately truncate the scan, but
            // can never fabricate or alter a surfaced record unless the
            // flip landed outside any payload — header/len flips drop
            // records, payload flips fail the crc).
            for got in &scan.ops {
                assert!(ops.contains(got), "byte {byte} surfaced altered record");
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sealing_a_torn_segment_makes_it_scan_clean() {
        let dir = tmpdir("seal");
        let mut w = WalWriter::create(&dir, 0, WalSync::Os).unwrap();
        for op in &sample_ops() {
            w.append(op).unwrap();
        }
        drop(w);
        let path = segment_path(&dir, 0);
        let full = fs::read(&path).unwrap();
        fs::write(&path, &full[..full.len() - 3]).unwrap(); // tear mid-record
        let scan = read_segment(&path).unwrap();
        assert!(scan.torn);
        truncate_segment(&path, scan.valid_len).unwrap();
        let sealed = read_segment(&path).unwrap();
        assert!(!sealed.torn, "a sealed segment must scan clean");
        assert_eq!(sealed.valid_len, scan.valid_len);
        assert_eq!(sealed.ops[..], sample_ops()[..sample_ops().len() - 1]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_listing_sorted() {
        let dir = tmpdir("list");
        for seq in [7u64, 1, 12] {
            WalWriter::create(&dir, seq, WalSync::Os).unwrap();
        }
        fs::write(dir.join("not-a-wal.txt"), b"x").unwrap();
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![1, 7, 12]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_sync_parses() {
        assert_eq!(WalSync::parse("os"), Some(WalSync::Os));
        assert_eq!(WalSync::parse("always"), Some(WalSync::Always));
        assert_eq!(WalSync::parse("never"), None);
        assert_eq!(WalSync::Always.as_str(), "always");
    }
}
