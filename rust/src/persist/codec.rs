//! Binary codec shared by the WAL and snapshot formats: little-endian
//! primitives, length-prefixed strings/vectors, and an IEEE CRC-32 (the
//! zlib/gzip polynomial, table-driven).
//!
//! Everything on the read side is bounds- and checksum-checked and
//! returns `Err` on malformed input — the corruption fuzzer feeds these
//! readers arbitrary bytes, so no code path here may panic.

/// Decode failure. Carries a human-readable reason; recovery treats any
/// decode failure as "stop here" (torn tail) or "discard this file"
/// (corrupt snapshot), never as a crash.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeError(pub String);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decode error: {}", self.0)
    }
}

pub type DecodeResult<T> = Result<T, DecodeError>;

fn err<T>(msg: impl Into<String>) -> DecodeResult<T> {
    Err(DecodeError(msg.into()))
}

// --- CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) ---------------------

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC-32 of `bytes` (same as zlib's `crc32(0, ...)`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// --- encoding ------------------------------------------------------------

pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed (u32) UTF-8 string.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Length-prefixed (u32 count) f32 vector, element-wise LE bit patterns —
/// round-trips NaNs and signed zeros exactly, so loaded embeddings are
/// bit-identical to what was stored.
pub fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        buf.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

// --- decoding ------------------------------------------------------------

/// Cursor over an immutable byte slice. Every read is bounds-checked.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> DecodeResult<&'a [u8]> {
        if self.remaining() < n {
            return err(format!("truncated: wanted {n} bytes, have {}", self.remaining()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Raw byte slice of exactly `n` bytes.
    pub fn bytes(&mut self, n: usize) -> DecodeResult<&'a [u8]> {
        self.take(n)
    }

    pub fn u8(&mut self) -> DecodeResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> DecodeResult<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> DecodeResult<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn str(&mut self) -> DecodeResult<String> {
        let len = self.u32()? as usize;
        // Guard absurd lengths before allocating (a flipped length byte
        // must not trigger a multi-GB allocation).
        if len > self.remaining() {
            return err(format!("string length {len} exceeds remaining {}", self.remaining()));
        }
        match std::str::from_utf8(self.take(len)?) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => err("invalid utf-8 in string"),
        }
    }

    pub fn f32s(&mut self) -> DecodeResult<Vec<f32>> {
        let n = self.u32()? as usize;
        if n.saturating_mul(4) > self.remaining() {
            return err(format!("f32 vector of {n} exceeds remaining {}", self.remaining()));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(self.u32()?));
        }
        Ok(v)
    }

    /// Read a list length, guarding against lengths that cannot possibly
    /// fit in the remaining bytes (each element needs >= `min_elem_bytes`).
    pub fn list_len(&mut self, min_elem_bytes: usize) -> DecodeResult<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return err(format!("list of {n} exceeds remaining {}", self.remaining()));
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn roundtrip_primitives() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX);
        put_str(&mut buf, "héllo wörld");
        put_f32s(&mut buf, &[0.0, -0.0, 1.5, f32::NAN, f32::MIN_POSITIVE]);
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.str().unwrap(), "héllo wörld");
        let v = r.f32s().unwrap();
        assert_eq!(v[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(v[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(v[2], 1.5);
        assert!(v[3].is_nan());
        assert_eq!(v[4], f32::MIN_POSITIVE);
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut buf = Vec::new();
        put_str(&mut buf, "hello");
        for cut in 0..buf.len() {
            let mut r = Reader::new(&buf[..cut]);
            assert!(r.str().is_err(), "cut at {cut} must fail cleanly");
        }
    }

    #[test]
    fn absurd_lengths_rejected_before_allocating() {
        // A string claiming u32::MAX bytes with a 4-byte body.
        let mut buf = Vec::new();
        put_u32(&mut buf, u32::MAX);
        buf.extend_from_slice(b"abcd");
        assert!(Reader::new(&buf).str().is_err());
        let mut buf2 = Vec::new();
        put_u32(&mut buf2, u32::MAX);
        assert!(Reader::new(&buf2).f32s().is_err());
    }
}
