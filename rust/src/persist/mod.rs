//! Crash-safe durability: write-ahead log + periodic snapshots.
//!
//! The cache itself is an in-memory structure; this module makes it
//! survive restarts. Every mutation is appended to a checksummed WAL
//! ([`wal`]) *after* it is applied in memory; a periodic snapshot
//! ([`snapshot`]) captures the full state — entries, embeddings, the id
//! allocator, and the serialized HNSW graph — then truncates the log.
//!
//! Recovery ([`Persistence::open`]) loads the newest decodable snapshot,
//! re-installs the persisted graph (falling back to re-indexing from raw
//! embeddings when the graph blob is corrupt or from a different format
//! version), replays the WAL suffix, and re-anchors wall-clock expiries
//! onto the process's monotonic clock. A torn WAL tail — the normal
//! aftermath of SIGKILL mid-write — recovers the valid prefix; corrupt
//! bytes anywhere are skipped or rejected, never panicked on.
//!
//! Crash-safety contract:
//! * an acknowledged mutation is in the WAL before the ack (under
//!   `wal_sync = "always"` it is also fsynced, surviving power loss);
//!   if the append itself fails (disk full, dir deleted) the request
//!   still serves, but the gap is surfaced as `wal_append_errors` on
//!   `/v1/metrics` — a non-zero value means durability is degraded
//!   until the next successful snapshot;
//! * WAL order matches in-memory apply order (the cache's journal gate
//!   holds across apply + append), so replaying the log reproduces the
//!   applied history even for racing dependent mutations;
//! * a torn segment is sealed (truncated to its valid prefix) during
//!   recovery, so segments written after the recovery are never
//!   mistaken for post-tear garbage by a later recovery;
//! * snapshots become visible only via atomic rename — a crash mid-
//!   snapshot leaves the previous snapshot + full WAL intact;
//! * recovery never serves a record that fails its checksum.

pub mod codec;
pub mod snapshot;
pub mod wal;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cache::{CacheConfig, CacheJournal, CachedEntry, SemanticCache};
use crate::error::Result;
use crate::index::{HnswIndex, VectorIndex};
use crate::metrics::Metrics;
use crate::store::Clock;

pub use snapshot::{list_snapshots, Snapshot};
pub use wal::{WalOp, WalSync, WalWriter};

/// Durability settings (see `data_dir`, `snapshot_interval_secs`,
/// `wal_sync` in [`crate::config::Config`]).
#[derive(Debug, Clone)]
pub struct PersistConfig {
    /// Directory holding WAL segments and snapshots. Created on open.
    pub data_dir: PathBuf,
    /// Seconds between automatic snapshots.
    pub snapshot_interval_secs: u64,
    /// WAL fsync policy.
    pub wal_sync: WalSync,
}

impl PersistConfig {
    /// Build from the flat app config; `None` when no `data_dir` is set
    /// (durability off — the seed's in-memory behavior).
    pub fn from_app_config(cfg: &crate::config::Config) -> Option<PersistConfig> {
        if cfg.data_dir.is_empty() {
            return None;
        }
        Some(PersistConfig {
            data_dir: PathBuf::from(&cfg.data_dir),
            snapshot_interval_secs: cfg.snapshot_interval_secs,
            // Validated in Config::validate; default to Os defensively.
            wal_sync: WalSync::parse(&cfg.wal_sync).unwrap_or(WalSync::Os),
        })
    }
}

/// What the startup recovery pass found and did.
#[derive(Debug, Default, Clone)]
pub struct RecoveryReport {
    /// Entries restored live (snapshot + WAL replay, minus expiries).
    pub entries: usize,
    /// WAL records replayed after the snapshot.
    pub replayed: usize,
    /// Whether the WAL ended in a torn/corrupt tail that was discarded.
    pub torn_tail: bool,
    /// Whether a snapshot was loaded (vs. WAL-only or cold start).
    pub snapshot_loaded: bool,
    /// Partitions whose persisted graph was unusable (corrupt bytes or a
    /// different dump version) and were re-indexed from raw embeddings.
    pub reindexed_partitions: usize,
    /// Persisted entries whose TTL elapsed while the process was down.
    pub expired_during_downtime: usize,
}

/// Result of one snapshot pass.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotStats {
    /// Live entries captured.
    pub entries: usize,
    /// Snapshot file size in bytes.
    pub bytes: usize,
}

/// The durability engine: owns the WAL writer and the snapshot
/// procedure, and observes cache mutations via [`CacheJournal`].
pub struct Persistence {
    dir: PathBuf,
    sync: WalSync,
    wal: Mutex<WalWriter>,
    /// Serializes snapshot passes (the WAL mutex is only held for the
    /// rotation instant, not the whole pass).
    snap_lock: Mutex<()>,
    metrics: Arc<Metrics>,
}

impl Persistence {
    /// Recover cache state from `cfg.data_dir` (creating it if absent)
    /// and return the recovered cache with journaling attached, so every
    /// subsequent mutation is logged.
    pub fn open(
        cfg: &PersistConfig,
        cache_cfg: CacheConfig,
        clock: Arc<dyn Clock>,
        metrics: Arc<Metrics>,
    ) -> Result<(SemanticCache, Arc<Persistence>, RecoveryReport)> {
        let started = Instant::now();
        fs::create_dir_all(&cfg.data_dir)?;
        remove_stale_tmp(&cfg.data_dir);

        let cache = SemanticCache::with_clock(cache_cfg, clock);
        let mut report = RecoveryReport::default();

        // Newest decodable snapshot wins; corrupt ones are skipped (an
        // older snapshot plus a longer WAL replay is still correct,
        // because snapshots never outrun the log they truncate).
        let mut snap: Option<Snapshot> = None;
        for (_, path) in snapshot::list_snapshots(&cfg.data_dir)?.into_iter().rev() {
            if let Ok(bytes) = fs::read(&path) {
                if let Ok(s) = Snapshot::decode(&bytes) {
                    snap = Some(s);
                    break;
                }
            }
        }
        let mut replay_from = 0u64;
        if let Some(s) = snap {
            replay_from = s.wal_seq;
            report.snapshot_loaded = true;
            for dump in s.partitions {
                let p = cache.partition_for(&dump.tenant, dump.dim);
                let mut graph_installed = false;
                if let Some(bytes) = &dump.graph {
                    if p.index_is_hnsw() {
                        match HnswIndex::load(bytes) {
                            Ok(mut idx) if idx.dim() == dump.dim => {
                                // Loads default to the exact scan; re-apply
                                // the partition's configured kernel so a
                                // recovered graph searches exactly like one
                                // built live.
                                idx.set_quantized(p.quantized());
                                graph_installed = p.install_index(Box::new(idx));
                            }
                            _ => {}
                        }
                    }
                    if !graph_installed {
                        // Corrupt blob, version mismatch, or the server
                        // now runs a flat index: fall back to re-indexing
                        // from the raw embeddings below.
                        report.reindexed_partitions += 1;
                    }
                }
                for e in dump.entries {
                    if restore_counted(&cache, &dump.tenant, dump.dim, e.id, &e.embedding, e.entry, e.expires_wall_ms, &mut report) {
                        report.entries += 1;
                    }
                }
                p.bump_next_id(dump.next_id);
            }
        }

        // Replay the WAL suffix. Segments are strictly ordered; a torn
        // segment ends the replay (anything after it postdates the tear
        // and cannot be trusted to be a contiguous history).
        let segments = wal::list_segments(&cfg.data_dir)?;
        let mut last_seq = replay_from.saturating_sub(1).max(segments.last().map(|(s, _)| *s).unwrap_or(0));
        let mut stop = false;
        for (seq, path) in &segments {
            if *seq < replay_from || stop {
                continue;
            }
            let scan = wal::read_segment(path)?;
            for op in scan.ops {
                apply_op(&cache, op, &mut report);
                report.replayed += 1;
            }
            if scan.torn {
                report.torn_tail = true;
                stop = true;
                // Seal the torn segment to its valid prefix so the *next*
                // recovery scans it clean. Without this the tear is
                // re-detected on every restart, and the discard loop
                // below would then delete the segment this recovery is
                // about to start writing — silently losing every
                // mutation acknowledged between the two restarts.
                if let Err(e) = wal::truncate_segment(path, scan.valid_len) {
                    eprintln!(
                        "semcache: sealing torn wal segment {} failed: {e}",
                        path.display()
                    );
                }
                // Discard segments past the tear so a future recovery
                // cannot replay post-tear history after this prefix.
                for (s2, p2) in &segments {
                    if s2 > seq {
                        let _ = fs::remove_file(p2);
                    }
                }
            }
        }

        // Fresh segment strictly after everything on disk.
        last_seq = last_seq.max(replay_from);
        let next_seq = if segments.is_empty() && !report.snapshot_loaded {
            0
        } else {
            last_seq + 1
        };
        let writer = WalWriter::create(&cfg.data_dir, next_seq, cfg.wal_sync)?;

        let persistence = Arc::new(Persistence {
            dir: cfg.data_dir.clone(),
            sync: cfg.wal_sync,
            wal: Mutex::new(writer),
            snap_lock: Mutex::new(()),
            metrics: metrics.clone(),
        });
        cache.set_journal(persistence.clone());

        let ms = started.elapsed().as_millis() as u64;
        metrics.record_recovery(ms, report.entries as u64);
        Ok((cache, persistence, report))
    }

    /// Take a snapshot of `cache` and truncate the WAL it covers.
    ///
    /// Sequence: rotate the WAL (instantaneous, under the WAL mutex) so
    /// every later mutation lands in the new segment; sweep expired
    /// entries and compact tombstoned graphs (snapshots double as the
    /// durability tier's garbage collection); capture per-partition
    /// dumps; write the snapshot atomically; delete the segments and
    /// snapshots it supersedes. Mutations applied after the memory
    /// capture are in the new segment and replay idempotently.
    pub fn snapshot(&self, cache: &SemanticCache) -> Result<SnapshotStats> {
        let _guard = self.snap_lock.lock().unwrap();
        let new_seq = {
            let mut w = self.wal.lock().unwrap();
            let next = w.seq() + 1;
            *w = WalWriter::create(&self.dir, next, self.sync)?;
            next
        };
        for p in cache.partitions() {
            p.sweep_expired();
            if p.garbage_ratio() > 0.0 {
                p.rebuild();
            }
        }
        let snap = Snapshot {
            wal_seq: new_seq,
            wall_ms: cache.clock().wall_ms(),
            partitions: cache.partitions().iter().map(|p| p.dump()).collect(),
        };
        let entries = snap.entry_count();
        let bytes = snap.encode();
        snapshot::write_atomic(&self.dir, new_seq, &bytes)?;
        for (seq, path) in snapshot::list_snapshots(&self.dir)? {
            if seq < new_seq {
                let _ = fs::remove_file(path);
            }
        }
        for (seq, path) in wal::list_segments(&self.dir)? {
            if seq < new_seq {
                let _ = fs::remove_file(path);
            }
        }
        self.metrics.record_snapshot_written();
        Ok(SnapshotStats { entries, bytes: bytes.len() })
    }

    /// Data directory this engine persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn append(&self, op: &WalOp) {
        let mut w = self.wal.lock().unwrap();
        match w.append(op) {
            Ok(bytes) => self.metrics.record_wal_append(bytes),
            // An appender that cannot write (disk full, dir deleted)
            // must not take the serving path down, but the mutation was
            // already acknowledged — durability is degraded until the
            // next successful snapshot. Surface that on /v1/metrics
            // (`wal_append_errors`) so operators can alert on it instead
            // of discovering the gap at the next crash.
            Err(e) => {
                self.metrics.record_wal_append_error();
                eprintln!("semcache: wal append failed: {e}");
            }
        }
    }
}

impl CacheJournal for Persistence {
    fn log_insert(
        &self,
        tenant: &str,
        dim: usize,
        id: u64,
        embedding: &[f32],
        entry: &CachedEntry,
        expires_wall_ms: u64,
    ) {
        self.append(&WalOp::insert(tenant, dim, id, embedding, entry, expires_wall_ms));
    }

    fn log_remove(&self, tenant: &str, dim: usize, id: u64) {
        self.append(&WalOp::Remove { tenant: tenant.to_string(), dim: dim as u32, id });
    }

    fn log_evict(&self, tenant: &str, dim: usize, id: u64) {
        self.append(&WalOp::Evict { tenant: tenant.to_string(), dim: dim as u32, id });
    }

    fn log_clear(&self) {
        self.append(&WalOp::Clear);
    }
}

/// Apply one replayed WAL record to the cache.
///
/// `Evict` replays as a removal — recovery re-applies the logged history
/// verbatim and does not re-run budget enforcement itself; the logged
/// evictions *are* the enforcement decisions, so the recovered resident
/// set equals the pre-crash one (entries evicted before the crash stay
/// gone).
fn apply_op(cache: &SemanticCache, op: WalOp, report: &mut RecoveryReport) {
    match op {
        WalOp::Insert {
            tenant,
            dim,
            id,
            expires_wall_ms,
            cluster,
            latency_ms,
            question,
            response,
            embedding,
        } => {
            let entry = CachedEntry { question, response, cluster, latency_ms };
            if restore_counted(cache, &tenant, dim as usize, id, &embedding, entry, expires_wall_ms, report) {
                report.entries += 1;
            }
        }
        WalOp::Remove { tenant, dim, id } | WalOp::Evict { tenant, dim, id } => {
            if let Some(p) = cache.partition_if_exists_for(&tenant, dim as usize) {
                if p.remove_id(id) {
                    report.entries = report.entries.saturating_sub(1);
                }
            }
        }
        WalOp::Clear => {
            cache.clear();
            report.entries = 0;
        }
    }
}

/// Restore one entry, distinguishing "expired during downtime" from
/// malformed records when the restore is refused.
fn restore_counted(
    cache: &SemanticCache,
    tenant: &str,
    dim: usize,
    id: u64,
    embedding: &[f32],
    entry: CachedEntry,
    expires_wall_ms: u64,
    report: &mut RecoveryReport,
) -> bool {
    if dim == 0 || embedding.len() != dim {
        return false; // malformed record: drop, never panic
    }
    let p = cache.partition_for(tenant, dim);
    let restored = p.restore_entry(id, embedding, entry, expires_wall_ms);
    if !restored
        && embedding.len() == dim
        && expires_wall_ms != u64::MAX
        && expires_wall_ms <= cache.clock().wall_ms()
    {
        report.expired_during_downtime += 1;
    }
    restored
}

fn remove_stale_tmp(dir: &Path) {
    if let Ok(rd) = fs::read_dir(dir) {
        for entry in rd.flatten() {
            if entry.file_name().to_string_lossy().ends_with(".tmp") {
                let _ = fs::remove_file(entry.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::IndexKind;
    use crate::store::ManualClock;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("semcache-persist-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn pcfg(dir: &Path) -> PersistConfig {
        PersistConfig {
            data_dir: dir.to_path_buf(),
            snapshot_interval_secs: 60,
            wal_sync: WalSync::Os,
        }
    }

    fn ccfg() -> CacheConfig {
        CacheConfig::builder()
            .index(IndexKind::Hnsw)
            .ttl_ms(0)
            .build()
            .unwrap()
    }

    fn vec_for(i: u64, dim: usize) -> Vec<f32> {
        (0..dim).map(|d| ((i * 31 + d as u64 * 7) % 13) as f32 - 6.0).collect()
    }

    #[test]
    fn wal_only_restart_restores_entries() {
        let dir = tmpdir("walonly");
        let clock = Arc::new(ManualClock::new(1_000));
        let m = Arc::new(Metrics::new());
        let (cache, _p, rep) =
            Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), m.clone()).unwrap();
        assert_eq!(rep.entries, 0);
        for i in 0..20u64 {
            cache
                .try_insert(&format!("question {i}"), &vec_for(i, 8), &format!("answer {i}"))
                .unwrap();
        }
        assert_eq!(cache.len(), 20);
        drop(cache);

        let (cache2, _p2, rep2) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert!(!rep2.snapshot_loaded);
        assert_eq!(rep2.entries, 20);
        assert_eq!(cache2.len(), 20);
        let hit = cache2.lookup(&vec_for(7, 8)).expect("recovered entry must hit");
        assert_eq!(hit.entry.response, "answer 7");
        assert_eq!(m.wal_records.load(std::sync::atomic::Ordering::Relaxed), 20);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_truncates_wal_and_restores() {
        let dir = tmpdir("snap");
        let clock = Arc::new(ManualClock::new(1_000));
        let (cache, p, _) =
            Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                .unwrap();
        for i in 0..30u64 {
            cache.try_insert(&format!("q{i}"), &vec_for(i, 8), &format!("a{i}")).unwrap();
        }
        let stats = p.snapshot(&cache).unwrap();
        assert_eq!(stats.entries, 30);
        assert!(stats.bytes > 0);
        // Post-snapshot mutations land in the fresh segment.
        for i in 30..35u64 {
            cache.try_insert(&format!("q{i}"), &vec_for(i, 8), &format!("a{i}")).unwrap();
        }
        assert!(cache.remove_entry(8, 0));
        drop(cache);

        // Only the covered-by-snapshot segment was deleted.
        assert_eq!(snapshot::list_snapshots(&dir).unwrap().len(), 1);

        let (cache2, _p2, rep) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert!(rep.snapshot_loaded);
        assert_eq!(rep.replayed, 6, "5 inserts + 1 remove after the snapshot");
        assert_eq!(cache2.len(), 34);
        assert!(cache2.lookup(&vec_for(33, 8)).is_some());
        // Removed entry stays gone.
        let hit = cache2.lookup(&vec_for(0, 8));
        assert!(hit.is_none() || hit.unwrap().entry.response != "a0");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_is_durable() {
        let dir = tmpdir("clear");
        let clock = Arc::new(ManualClock::new(1_000));
        let (cache, _p, _) =
            Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                .unwrap();
        for i in 0..5u64 {
            cache.try_insert(&format!("q{i}"), &vec_for(i, 4), "a").unwrap();
        }
        cache.clear();
        cache.try_insert("survivor", &vec_for(99, 4), "alive").unwrap();
        drop(cache);
        let (cache2, _p2, rep) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert_eq!(rep.entries, 1);
        assert_eq!(cache2.lookup(&vec_for(99, 4)).unwrap().entry.response, "alive");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_segment_is_sealed_so_second_restart_keeps_post_crash_writes() {
        // Regression (review, high): recovery used to leave the torn
        // segment torn on disk, so the *next* restart re-detected the
        // tear and discarded every segment written after it — silently
        // losing all mutations acknowledged between two restarts.
        let dir = tmpdir("seal");
        let clock = Arc::new(ManualClock::new(1_000));
        {
            let (cache, _p, _) =
                Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                    .unwrap();
            for i in 0..10u64 {
                cache.try_insert(&format!("q{i}"), &vec_for(i, 8), &format!("a{i}")).unwrap();
            }
        }
        // Simulate SIGKILL mid-write: tear the tail of the only segment.
        let (_, seg0) = wal::list_segments(&dir).unwrap().pop().unwrap();
        let bytes = fs::read(&seg0).unwrap();
        fs::write(&seg0, &bytes[..bytes.len() - 5]).unwrap();

        // Restart #1: recovers the valid prefix, seals the tear, and
        // acknowledges new writes (which land in a fresh segment).
        {
            let (cache, _p, rep) =
                Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                    .unwrap();
            assert!(rep.torn_tail);
            assert_eq!(rep.entries, 9, "last record was torn, prefix recovers");
            for i in 10..15u64 {
                cache.try_insert(&format!("q{i}"), &vec_for(i, 8), &format!("a{i}")).unwrap();
            }
        }
        let scan = wal::read_segment(&seg0).unwrap();
        assert!(!scan.torn, "recovery must seal the torn segment");

        // Restart #2 (no crash in between): the post-tear segment holds
        // acknowledged history and must be replayed, not discarded.
        let (cache2, _p2, rep2) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert!(!rep2.torn_tail, "no new tear on a clean shutdown");
        assert_eq!(rep2.entries, 14, "9 pre-crash + 5 post-crash acked entries");
        let hit = cache2.lookup(&vec_for(12, 8)).expect("post-crash acked entry must survive");
        assert_eq!(hit.entry.response, "a12");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_restart_does_not_resurrect_evicted_entries() {
        // Regression: before evictions were journaled, a WAL-only warm
        // restart replayed every Insert record and brought back entries
        // the byte budget had already evicted — the recovered cache was
        // bigger than the pre-crash one and over budget.
        // Every "q{i}"/"a{i}" pair below has 2-byte question + response.
        let one = crate::eviction::entry_footprint(2, 2, 8);
        let budget_cfg = || {
            CacheConfig::builder()
                .index(IndexKind::Hnsw)
                .max_bytes(3 * one)
                .build()
                .unwrap()
        };
        let dir = tmpdir("evict");
        let clock = Arc::new(ManualClock::new(1_000));
        let survivors: Vec<u64> = {
            let (cache, _p, _) =
                Persistence::open(&pcfg(&dir), budget_cfg(), clock.clone(), Arc::new(Metrics::new()))
                    .unwrap();
            // 8 equal-footprint inserts through a 3-entry budget: 5 LRU
            // evictions, journaled as they happen.
            for i in 0..8u64 {
                cache.try_insert(&format!("q{i}"), &vec_for(i, 8), &format!("a{i}")).unwrap();
            }
            assert_eq!(cache.len(), 3);
            (5..8).collect()
        };

        let (cache2, _p2, rep) =
            Persistence::open(&pcfg(&dir), budget_cfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert_eq!(
            rep.entries, 3,
            "replay must net out journaled evictions, not resurrect all 8 inserts"
        );
        assert_eq!(cache2.len(), 3);
        assert!(cache2.bytes() <= 3 * one, "recovered cache must respect the byte budget");
        for i in 0..5u64 {
            let hit = cache2.lookup(&vec_for(i, 8));
            assert!(
                hit.is_none() || hit.unwrap().entry.response != format!("a{i}"),
                "evicted entry {i} resurrected by warm restart"
            );
        }
        for i in &survivors {
            assert_eq!(
                cache2.lookup(&vec_for(*i, 8)).expect("survivor must hit").entry.response,
                format!("a{i}")
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tenants_survive_restart_in_their_own_namespaces() {
        let dir = tmpdir("tenants");
        let clock = Arc::new(ManualClock::new(1_000));
        {
            let (cache, p, _) =
                Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                    .unwrap();
            let e = CachedEntry {
                question: "shared question".into(),
                response: "alice answer".into(),
                cluster: 0,
                latency_ms: 250.0,
            };
            cache.try_insert_entry_ttl_for("alice", &vec_for(1, 8), e, None).unwrap();
            // Snapshot covers alice; bob's insert rides the WAL suffix.
            p.snapshot(&cache).unwrap();
            let e2 = CachedEntry {
                question: "shared question".into(),
                response: "bob answer".into(),
                cluster: 0,
                latency_ms: 0.0,
            };
            cache.try_insert_entry_ttl_for("bob", &vec_for(1, 8), e2, None).unwrap();
        }
        let (cache2, _p2, rep) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert!(rep.snapshot_loaded);
        assert_eq!(rep.entries, 2);
        let a = cache2.lookup_with_opts_for("alice", &vec_for(1, 8), 0.8, None).unwrap();
        assert_eq!(a.entry.response, "alice answer");
        assert_eq!(a.entry.latency_ms, 250.0, "latency survives snapshot roundtrip");
        let b = cache2.lookup_with_opts_for("bob", &vec_for(1, 8), 0.8, None).unwrap();
        assert_eq!(b.entry.response, "bob answer");
        // A third tenant that never inserted still sees nothing.
        assert!(cache2.lookup_with_opts_for("carol", &vec_for(1, 8), 0.8, None).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_after_snapshot_only_no_wal_records() {
        // Snapshot then immediate restart: nothing to replay, graph loads.
        let dir = tmpdir("snaponly");
        let clock = Arc::new(ManualClock::new(1_000));
        let (cache, p, _) =
            Persistence::open(&pcfg(&dir), ccfg(), clock.clone(), Arc::new(Metrics::new()))
                .unwrap();
        for i in 0..10u64 {
            cache.try_insert(&format!("q{i}"), &vec_for(i, 6), &format!("a{i}")).unwrap();
        }
        p.snapshot(&cache).unwrap();
        drop(cache);
        let (cache2, _p2, rep) =
            Persistence::open(&pcfg(&dir), ccfg(), clock, Arc::new(Metrics::new())).unwrap();
        assert!(rep.snapshot_loaded);
        assert_eq!(rep.replayed, 0);
        assert_eq!(rep.reindexed_partitions, 0, "persisted graph must load");
        assert_eq!(cache2.len(), 10);
        let _ = fs::remove_dir_all(&dir);
    }
}
