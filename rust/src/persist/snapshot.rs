//! Point-in-time snapshots of the whole cache: every live entry (id,
//! absolute expiry, question, response, embedding) per partition plus an
//! optional serialized HNSW graph, checksummed and written atomically
//! (temp file + fsync + rename). A snapshot records the WAL sequence
//! number it covers up to, so recovery replays only the suffix.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::cache::{CachedEntry, EntryDump, PartitionDump};

use super::codec::{self, DecodeError, DecodeResult, Reader};

/// Snapshot file header. v2 (`SCSNAP02`) added the tenant namespace per
/// partition and the latency cost per entry; a v1 snapshot fails the
/// magic check and recovery falls back to older snapshots / cold start.
pub const SNAP_MAGIC: &[u8; 8] = b"SCSNAP02";

/// A decoded snapshot.
#[derive(Debug)]
pub struct Snapshot {
    /// First WAL segment *not* folded into this snapshot: recovery
    /// replays segments with `seq >= wal_seq`.
    pub wal_seq: u64,
    /// Wall-clock ms when the snapshot was taken.
    pub wall_ms: u64,
    pub partitions: Vec<PartitionDump>,
}

impl Snapshot {
    pub fn entry_count(&self) -> usize {
        self.partitions.iter().map(|p| p.entries.len()).sum()
    }

    /// Serialize to `SCSNAP02 | crc32(body) | body`.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        codec::put_u64(&mut body, self.wal_seq);
        codec::put_u64(&mut body, self.wall_ms);
        codec::put_u32(&mut body, self.partitions.len() as u32);
        for p in &self.partitions {
            codec::put_str(&mut body, &p.tenant);
            codec::put_u64(&mut body, p.dim as u64);
            codec::put_u64(&mut body, p.next_id);
            codec::put_u32(&mut body, p.entries.len() as u32);
            for e in &p.entries {
                codec::put_u64(&mut body, e.id);
                codec::put_u64(&mut body, e.expires_wall_ms);
                codec::put_u64(&mut body, e.entry.cluster);
                codec::put_u64(&mut body, e.entry.latency_ms.to_bits());
                codec::put_str(&mut body, &e.entry.question);
                codec::put_str(&mut body, &e.entry.response);
                codec::put_f32s(&mut body, &e.embedding);
            }
            match &p.graph {
                Some(bytes) => {
                    codec::put_u8(&mut body, 1);
                    codec::put_u32(&mut body, bytes.len() as u32);
                    body.extend_from_slice(bytes);
                }
                None => codec::put_u8(&mut body, 0),
            }
        }
        let mut out = Vec::with_capacity(body.len() + 12);
        out.extend_from_slice(SNAP_MAGIC);
        codec::put_u32(&mut out, codec::crc32(&body));
        out.extend_from_slice(&body);
        out
    }

    /// Decode and verify a snapshot blob. Any corruption — bad magic,
    /// checksum mismatch, malformed body — is an error; recovery falls
    /// back to the previous snapshot (or an empty cache), never panics.
    pub fn decode(bytes: &[u8]) -> DecodeResult<Snapshot> {
        if bytes.len() < SNAP_MAGIC.len() + 4 {
            return Err(DecodeError("snapshot shorter than header".into()));
        }
        if &bytes[..SNAP_MAGIC.len()] != SNAP_MAGIC {
            return Err(DecodeError("bad snapshot magic".into()));
        }
        let crc = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        let body = &bytes[12..];
        if codec::crc32(body) != crc {
            return Err(DecodeError("snapshot checksum mismatch".into()));
        }
        let mut r = Reader::new(body);
        let wal_seq = r.u64()?;
        let wall_ms = r.u64()?;
        let n_parts = r.list_len(13)?;
        let mut partitions = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            let tenant = r.str()?;
            let dim = r.u64()? as usize;
            if dim == 0 {
                return Err(DecodeError("snapshot partition dim 0".into()));
            }
            let next_id = r.u64()?;
            let n_entries = r.list_len(36)?;
            let mut entries = Vec::with_capacity(n_entries);
            for _ in 0..n_entries {
                let id = r.u64()?;
                let expires_wall_ms = r.u64()?;
                let cluster = r.u64()?;
                let latency_ms = f64::from_bits(r.u64()?);
                let question = r.str()?;
                let response = r.str()?;
                let embedding = r.f32s()?;
                if embedding.len() != dim {
                    return Err(DecodeError(format!(
                        "snapshot entry embedding len {} != dim {dim}",
                        embedding.len()
                    )));
                }
                entries.push(EntryDump {
                    id,
                    expires_wall_ms,
                    entry: CachedEntry { question, response, cluster, latency_ms },
                    embedding,
                });
            }
            let graph = match r.u8()? {
                0 => None,
                1 => {
                    let len = r.list_len(1)?;
                    Some(r.bytes(len)?.to_vec())
                }
                other => return Err(DecodeError(format!("bad graph flag {other}"))),
            };
            partitions.push(PartitionDump { tenant, dim, next_id, entries, graph });
        }
        if !r.is_empty() {
            return Err(DecodeError("trailing bytes in snapshot".into()));
        }
        Ok(Snapshot { wal_seq, wall_ms, partitions })
    }
}

/// Path of snapshot `seq` in `dir`.
pub fn snapshot_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snapshot-{seq:016}.snap"))
}

/// All snapshot files in `dir`, sorted by ascending sequence number.
pub fn list_snapshots(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name.strip_prefix("snapshot-").and_then(|s| s.strip_suffix(".snap")) {
            if let Ok(seq) = num.parse::<u64>() {
                out.push((seq, entry.path()));
            }
        }
    }
    out.sort_by_key(|(seq, _)| *seq);
    Ok(out)
}

/// Write `bytes` as snapshot `seq`: temp file in the same directory,
/// fsync, then atomic rename — a crash mid-write leaves either the old
/// state or the complete new snapshot, never a half-written file under
/// the final name.
pub fn write_atomic(dir: &Path, seq: u64, bytes: &[u8]) -> std::io::Result<PathBuf> {
    let final_path = snapshot_path(dir, seq);
    let tmp_path = dir.join(format!("snapshot-{seq:016}.tmp"));
    {
        let mut f = File::create(&tmp_path)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp_path, &final_path)?;
    // Persist the rename itself (directory entry) on a best-effort basis;
    // some filesystems don't support fsync on directories.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(final_path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            wal_seq: 5,
            wall_ms: 1_700_000_000_000,
            partitions: vec![
                PartitionDump {
                    tenant: "default".into(),
                    dim: 3,
                    next_id: 11,
                    entries: vec![
                        EntryDump {
                            id: 4,
                            expires_wall_ms: u64::MAX,
                            entry: CachedEntry {
                                question: "what is the capital of france".into(),
                                response: "Paris".into(),
                                cluster: 2,
                                latency_ms: 731.25,
                            },
                            embedding: vec![0.6, 0.8, 0.0],
                        },
                        EntryDump {
                            id: 10,
                            expires_wall_ms: 1_700_000_100_000,
                            entry: CachedEntry {
                                question: "q2".into(),
                                response: String::new(),
                                cluster: 0,
                                latency_ms: 0.0,
                            },
                            embedding: vec![-1.0, 0.0, 0.25],
                        },
                    ],
                    graph: Some(vec![1, 2, 3, 4, 5]),
                },
                PartitionDump {
                    tenant: "bot-7".into(),
                    dim: 2,
                    next_id: 0,
                    entries: Vec::new(),
                    graph: None,
                },
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let snap = sample();
        let bytes = snap.encode();
        let back = Snapshot::decode(&bytes).unwrap();
        assert_eq!(back.wal_seq, 5);
        assert_eq!(back.wall_ms, 1_700_000_000_000);
        assert_eq!(back.partitions.len(), 2);
        let p = &back.partitions[0];
        assert_eq!(p.tenant, "default");
        assert_eq!((p.dim, p.next_id), (3, 11));
        assert_eq!(p.entries.len(), 2);
        assert_eq!(p.entries[0].entry.response, "Paris");
        assert_eq!(p.entries[0].entry.latency_ms, 731.25, "latency bits roundtrip exactly");
        assert_eq!(p.entries[1].embedding, vec![-1.0, 0.0, 0.25]);
        assert_eq!(p.graph.as_deref(), Some(&[1u8, 2, 3, 4, 5][..]));
        assert_eq!(back.partitions[1].tenant, "bot-7");
        assert!(back.partitions[1].graph.is_none());
        assert_eq!(back.entry_count(), 2);
    }

    #[test]
    fn every_truncation_and_flip_is_rejected_cleanly() {
        let bytes = sample().encode();
        for cut in 0..bytes.len() {
            assert!(Snapshot::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        for byte in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x01;
            // Either rejected or (only if the flip is in the magic? no —
            // magic flips fail too) — every single-bit flip must fail the
            // magic check or the crc.
            assert!(Snapshot::decode(&bad).is_err(), "byte={byte}");
        }
    }

    #[test]
    fn atomic_write_and_listing() {
        let dir = std::env::temp_dir().join(format!("semcache-snap-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let bytes = sample().encode();
        write_atomic(&dir, 9, &bytes).unwrap();
        write_atomic(&dir, 2, &bytes).unwrap();
        fs::write(dir.join("snapshot-zzz.snap"), b"junk").unwrap();
        let snaps = list_snapshots(&dir).unwrap();
        assert_eq!(snaps.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![2, 9]);
        let loaded = Snapshot::decode(&fs::read(&snaps[1].1).unwrap()).unwrap();
        assert_eq!(loaded.wal_seq, 5);
        // No temp droppings left behind.
        assert!(!dir.join("snapshot-0000000000000009.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
