//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! This is the only place the crate touches the `xla` crate. The compile
//! path (`python/compile/aot.py`) lowers the JAX/Pallas programs to **HLO
//! text** (not serialized protos — jax >= 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids). At
//! startup the coordinator loads every artifact listed in the manifest,
//! compiles it once on the PJRT CPU client, and keeps the loaded
//! executables around for the life of the process. Python is never on the
//! request path.

#[cfg(feature = "pjrt")]
mod executable;
#[cfg(not(feature = "pjrt"))]
#[path = "executable_stub.rs"]
mod executable;
mod manifest;

pub use executable::{Executable, Runtime};
pub use manifest::{ArtifactManifest, ArtifactSpec, ModelParams};

/// True when the crate was built with the `pjrt` feature — i.e. the
/// xla-backed execution path is compiled in. Without it, [`Runtime`] and
/// `PjrtEncoder` are stubs whose constructors return a descriptive error
/// and the native encoder is the only usable backend.
pub const fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// True when the PJRT path is actually usable: the runtime is compiled
/// in *and* the AOT artifacts have been built. The single gate callers
/// should use when choosing between the PJRT and native encoders.
pub fn pjrt_ready() -> bool {
    pjrt_enabled() && artifacts_available()
}

use std::path::{Path, PathBuf};

/// Default artifacts directory, relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$SEMCACHE_ARTIFACTS` if set, else
/// `artifacts/` under the current directory, else under the crate root
/// (so `cargo test` / examples work from any cwd).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SEMCACHE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    // CARGO_MANIFEST_DIR is baked at compile time; fall back to it so tests
    // invoked from subdirectories still find the artifacts.
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR);
    if manifest_dir.exists() {
        return manifest_dir;
    }
    cwd
}

/// True when the AOT artifacts have been built (`make artifacts`).
/// Tests that need PJRT skip themselves when this is false so `cargo test`
/// stays green on a fresh checkout.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
