//! Artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py` and read here at startup. It names every
//! lowered HLO module plus its I/O shapes and the model hyperparameters
//! (so the Rust tokenizer/native encoder stay in lock-step with the AOT
//! encoder without re-parsing HLO).

use std::path::Path;

use crate::error::{Context, Result};

use crate::json::{self, Value};

/// One AOT-compiled module.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Manifest key, e.g. `encoder_b8` (encoder at batch size 8).
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactSpec>,
    /// Model hyperparameters as emitted by aot.py (dim, layers, vocab, ...).
    pub model: ModelParams,
}

/// Encoder hyperparameters shared between the Python AOT model and the
/// Rust native reference implementation. Both sides derive weights from
/// the same splitmix64 seed, so these numbers fully determine the model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    pub vocab_size: usize,
    pub dim: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for ModelParams {
    fn default() -> Self {
        // MiniLM-L6-v2-style geometry (384-d, 6 layers) scaled for the
        // synthetic-semantics encoder; see DESIGN.md §Embedding-Substitution.
        Self { vocab_size: 4096, dim: 384, hidden: 768, layers: 4, heads: 6, seq_len: 32, seed: 0x5eed_cafe }
    }
}

impl ArtifactManifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        Self::from_value(&v)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let mut artifacts = Vec::new();
        for a in v.get("artifacts").as_array().context("manifest: 'artifacts' array")? {
            artifacts.push(ArtifactSpec {
                name: a.get("name").as_str().context("artifact name")?.to_string(),
                file: a.get("file").as_str().context("artifact file")?.to_string(),
                input_shapes: parse_shapes(a.get("input_shapes"))?,
                output_shapes: parse_shapes(a.get("output_shapes"))?,
            });
        }
        let m = v.get("model");
        let d = ModelParams::default();
        let model = ModelParams {
            vocab_size: m.get("vocab_size").as_usize().unwrap_or(d.vocab_size),
            dim: m.get("dim").as_usize().unwrap_or(d.dim),
            hidden: m.get("hidden").as_usize().unwrap_or(d.hidden),
            layers: m.get("layers").as_usize().unwrap_or(d.layers),
            heads: m.get("heads").as_usize().unwrap_or(d.heads),
            seq_len: m.get("seq_len").as_usize().unwrap_or(d.seq_len),
            seed: m.get("seed").as_i64().map(|s| s as u64).unwrap_or(d.seed),
        };
        Ok(Self { artifacts, model })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All encoder batch sizes present in the manifest (`encoder_b{N}`),
    /// ascending — the batcher picks the smallest one >= pending count.
    pub fn encoder_batch_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .artifacts
            .iter()
            .filter_map(|a| a.name.strip_prefix("encoder_b").and_then(|s| s.parse().ok()))
            .collect();
        sizes.sort_unstable();
        sizes
    }
}

fn parse_shapes(v: &Value) -> Result<Vec<Vec<usize>>> {
    let mut out = Vec::new();
    for shape in v.as_array().context("shape list")? {
        let dims = shape
            .as_array()
            .context("shape dims")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        out.push(dims);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "artifacts": [
            {"name": "encoder_b1", "file": "encoder_b1.hlo.txt",
             "input_shapes": [[1, 32]], "output_shapes": [[1, 384]]},
            {"name": "encoder_b8", "file": "encoder_b8.hlo.txt",
             "input_shapes": [[8, 32]], "output_shapes": [[8, 384]]},
            {"name": "scorer", "file": "scorer.hlo.txt",
             "input_shapes": [[384], [1024, 384]], "output_shapes": [[16], [16]]}
        ],
        "model": {"vocab_size": 4096, "dim": 384, "hidden": 768,
                  "layers": 4, "heads": 6, "seq_len": 32, "seed": 1589069518}
    }"#;

    #[test]
    fn parses_sample() {
        let v = crate::json::parse(SAMPLE).unwrap();
        let m = ArtifactManifest::from_value(&v).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.find("scorer").unwrap().input_shapes[1], vec![1024, 384]);
        assert_eq!(m.encoder_batch_sizes(), vec![1, 8]);
        assert_eq!(m.model.dim, 384);
    }

    #[test]
    fn missing_fields_error() {
        let v = crate::json::parse(r#"{"artifacts": [{"name": "x"}]}"#).unwrap();
        assert!(ArtifactManifest::from_value(&v).is_err());
    }
}
