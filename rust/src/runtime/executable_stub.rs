//! Stub [`Runtime`]/[`Executable`] compiled when the `pjrt` feature is
//! off (the default in the offline build, which cannot vendor the `xla`
//! crate). Constructors return a descriptive error; the instance methods
//! are statically unreachable because no value can ever be constructed
//! (the types hold an uninhabited field).

use std::path::Path;

use crate::error::{bail, Result};

const UNAVAILABLE: &str = "semcache was built without the `pjrt` feature: \
     the PJRT runtime is unavailable (rebuild with `--features pjrt` and a \
     vendored `xla` crate, or use the native encoder)";

/// Stub of the PJRT client + compiled-executable registry.
pub struct Runtime {
    never: std::convert::Infallible,
}

/// Stub of a compiled HLO module.
pub struct Executable {
    never: std::convert::Infallible,
}

impl Runtime {
    /// Always fails: the xla-backed runtime is not compiled in.
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!("{}", UNAVAILABLE)
    }

    pub fn get(&self, _name: &str) -> Result<&Executable> {
        match self.never {}
    }

    pub fn has(&self, _name: &str) -> bool {
        match self.never {}
    }

    pub fn names(&self) -> Vec<&str> {
        match self.never {}
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }
}

impl Executable {
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }

    pub fn run_mixed(
        &self,
        _int_inputs: &[(&[i64], &[usize])],
        _f32_inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Runtime::load(Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
