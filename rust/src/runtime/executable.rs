//! Compiled-executable wrapper around the PJRT CPU client.
//!
//! One [`Runtime`] per process; one [`Executable`] per AOT artifact. The
//! embedding batcher thread owns the encoder executables and services a
//! channel, so PJRT is never shared across threads mid-call.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::error::{bail, Context, Result};

use super::manifest::ArtifactManifest;

/// Process-wide PJRT client plus the compiled executables from the
/// artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, Executable>,
}

/// A single compiled HLO module with its I/O metadata from the manifest.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Shapes of the expected inputs, e.g. `[[8, 64]]` for a batch-8 encoder.
    pub input_shapes: Vec<Vec<usize>>,
    /// Shapes of the tuple outputs.
    pub output_shapes: Vec<Vec<usize>>,
    /// Wall-clock spent compiling this module (startup cost accounting).
    pub compile_time_ms: f64,
}

impl Runtime {
    /// Create a PJRT CPU client and compile every artifact in `dir`'s
    /// manifest. Fails if the manifest or any HLO file is missing.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading artifact manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for spec in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            executables.insert(
                spec.name.clone(),
                Executable {
                    exe,
                    input_shapes: spec.input_shapes.clone(),
                    output_shapes: spec.output_shapes.clone(),
                    compile_time_ms: t0.elapsed().as_secs_f64() * 1e3,
                },
            );
        }
        Ok(Self { client, executables })
    }

    /// Look up a compiled executable by manifest name (e.g. `encoder_b8`).
    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Upload an f32 tensor to the device once; the returned buffer can be
    /// passed to [`Executable::run_buffers`] any number of times. Used to
    /// keep the encoder weights resident instead of copying ~16 MB per call.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .context("uploading f32 buffer to device")
    }

    /// Upload an i64 tensor (token ids).
    pub fn upload_i64(&self, data: &[i64], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .context("uploading i64 buffer to device")
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

impl Executable {
    /// Execute with f32 inputs, returning the flattened f32 tuple outputs.
    ///
    /// Inputs are `(data, shape)` pairs; shapes must match the manifest.
    /// The AOT path lowers with `return_tuple=True`, so outputs always come
    /// back as a tuple which we destructure element-wise.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().enumerate() {
            let n: usize = shape.iter().product();
            if data.len() != n {
                bail!("input {i}: got {} elems, shape {:?} wants {n}", data.len(), shape);
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input {i} to {dims:?}"))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing module")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.unpack_tuple(result)
    }

    /// Execute with i64 (token-id) inputs followed by f32 inputs.
    /// JAX int32 inputs are avoided: we lower the encoder with i64 token
    /// ids to match `Literal::vec1(&[i64])` exactly.
    pub fn run_mixed(
        &self,
        int_inputs: &[(&[i64], &[usize])],
        f32_inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::new();
        for (data, shape) in int_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping i64 input to {dims:?}"))?,
            );
        }
        for (data, shape) in f32_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping f32 input to {dims:?}"))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing module")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.unpack_tuple(result)
    }

    /// Execute with pre-uploaded device buffers (zero host→device copies
    /// for the resident arguments). Order must match the HLO signature.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let result = self.exe.execute_b(args).context("executing module")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.unpack_tuple(result)
    }

    fn unpack_tuple(&self, result: xla::Literal) -> Result<Vec<Vec<f32>>> {
        let elems = result.to_tuple().context("destructuring output tuple")?;
        let mut out = Vec::with_capacity(elems.len());
        for lit in elems {
            out.push(lit.to_vec::<f32>().context("reading f32 output")?);
        }
        Ok(out)
    }
}
