//! PJRT-backed encoder: runs the AOT-compiled JAX/Pallas encoder.
//!
//! Weights are generated once (same splitmix64 streams as the compile
//! path), uploaded to device buffers once, and reused for every call —
//! per-request host→device traffic is just the (B, S) token tensor.
//! Batches are padded up to the smallest compiled batch size; the
//! coordinator's batcher picks sizes to minimize padding waste.

use std::sync::Arc;

use crate::error::{bail, Context, Result};

use crate::runtime::{ModelParams, Runtime};
use crate::tokenizer::Tokenizer;

use super::weights::EncoderWeights;

/// Encoder over AOT artifacts (`encoder_b{N}` in the manifest).
pub struct PjrtEncoder {
    runtime: Arc<Runtime>,
    params: ModelParams,
    tokenizer: Tokenizer,
    /// Ascending compiled batch sizes.
    batch_sizes: Vec<usize>,
    /// Weight device buffers, in executable-signature order.
    weight_buffers: Vec<xla::PjRtBuffer>,
}

impl PjrtEncoder {
    /// Build from a loaded runtime; generates + uploads weights.
    pub fn new(runtime: Arc<Runtime>, params: ModelParams, batch_sizes: Vec<usize>) -> Result<Self> {
        if batch_sizes.is_empty() {
            bail!("no encoder_b* artifacts in manifest");
        }
        for &b in &batch_sizes {
            let name = format!("encoder_b{b}");
            if !runtime.has(&name) {
                bail!("manifest missing {name}");
            }
        }
        let weights = EncoderWeights::generate(&params);
        let mut weight_buffers = Vec::new();
        for (data, shape) in weights.flat_inputs() {
            weight_buffers.push(
                runtime
                    .upload_f32(data, &shape)
                    .context("uploading encoder weights to device")?,
            );
        }
        let tokenizer = Tokenizer::new(params.vocab_size, params.seq_len);
        Ok(Self { runtime, params, tokenizer, batch_sizes, weight_buffers })
    }

    /// Smallest compiled batch size >= n (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        *self
            .batch_sizes
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.batch_sizes.last().expect("non-empty"))
    }

    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().expect("non-empty")
    }

    /// Encode one padded chunk (`texts.len() <= max_batch`).
    fn encode_chunk(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let b = self.pick_batch(texts.len());
        let s = self.params.seq_len;
        let d = self.params.dim;
        let mut tokens = vec![0i64; b * s];
        for (i, t) in texts.iter().enumerate() {
            tokens[i * s..(i + 1) * s].copy_from_slice(&self.tokenizer.encode(t));
        }
        let exe = self.runtime.get(&format!("encoder_b{b}"))?;
        let tok_buf = self.runtime.upload_i64(&tokens, &[b, s])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_buffers.iter());
        let outputs = exe.run_buffers(&args)?;
        let flat = &outputs[0];
        Ok(texts.iter().enumerate().map(|(i, _)| flat[i * d..(i + 1) * d].to_vec()).collect())
    }
}

// NOTE: `PjrtEncoder` deliberately does NOT implement the `Encoder`
// trait: `xla::PjRtClient` is `Rc`-based and therefore !Send, so the
// PJRT path lives on a dedicated batcher thread (`EmbeddingService`)
// whose handle implements `Encoder` for the rest of the system.
impl PjrtEncoder {
    pub fn dim(&self) -> usize {
        self.params.dim
    }

    pub fn params(&self) -> &ModelParams {
        &self.params
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// Encode any number of texts, chunking by the largest compiled batch.
    pub fn encode_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(self.max_batch()) {
            out.extend(self.encode_chunk(chunk)?);
        }
        Ok(out)
    }

    pub fn encode_text(&self, text: &str) -> Result<Vec<f32>> {
        Ok(self.encode_batch(&[text])?.pop().expect("one embedding"))
    }

    /// Load artifacts from the default directory and build the encoder.
    pub fn from_artifacts_dir(dir: &std::path::Path) -> Result<Self> {
        let manifest = crate::runtime::ArtifactManifest::load(&dir.join("manifest.json"))?;
        let batch_sizes = manifest.encoder_batch_sizes();
        let params = manifest.model.clone();
        let runtime = Arc::new(Runtime::load(dir)?);
        Self::new(runtime, params, batch_sizes)
    }
}
