//! Stub `PjrtEncoder` compiled when the `pjrt` feature is off (the
//! default in the offline build). [`PjrtEncoder::from_artifacts_dir`]
//! returns a descriptive error, so callers that gate on
//! [`crate::runtime::pjrt_enabled`] (or handle the error) fall back to
//! the native encoder; the instance methods are statically unreachable.

use std::path::Path;

use crate::error::{bail, Result};
use crate::runtime::ModelParams;
use crate::tokenizer::Tokenizer;

const UNAVAILABLE: &str = "semcache was built without the `pjrt` feature: \
     the PJRT encoder is unavailable (rebuild with `--features pjrt` and a \
     vendored `xla` crate, or use the native encoder)";

/// Stub of the AOT-artifact encoder.
pub struct PjrtEncoder {
    never: std::convert::Infallible,
}

impl PjrtEncoder {
    /// Always fails: the xla-backed encoder is not compiled in.
    pub fn from_artifacts_dir(_dir: &Path) -> Result<Self> {
        bail!("{}", UNAVAILABLE)
    }

    pub fn dim(&self) -> usize {
        match self.never {}
    }

    pub fn params(&self) -> &ModelParams {
        match self.never {}
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        match self.never {}
    }

    pub fn pick_batch(&self, _n: usize) -> usize {
        match self.never {}
    }

    pub fn max_batch(&self) -> usize {
        match self.never {}
    }

    pub fn encode_batch(&self, _texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }

    pub fn encode_text(&self, _text: &str) -> Result<Vec<f32>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_reports_missing_feature() {
        let err = PjrtEncoder::from_artifacts_dir(Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
