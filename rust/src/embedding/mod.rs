//! Embedding generation (paper §2.2).
//!
//! The paper supports pluggable embedding backends (OpenAI API or local
//! ONNX models); we mirror that with the [`Encoder`] trait and two
//! backends:
//!
//! * [`NativeEncoder`] — a pure-Rust forward pass, implements
//!   [`Encoder`] directly; used when artifacts are not built and as the
//!   parity oracle in `rust/tests/parity.rs`;
//! * [`PjrtEncoder`] — the artifact path: runs the AOT-compiled JAX/
//!   Pallas encoder (the *same* model: same generated weights, same
//!   formulas) through PJRT, weights resident on device, one executable
//!   per compiled batch size. PJRT objects are `Rc`-based (`!Send`), so
//!   `PjrtEncoder` does **not** implement [`Encoder`] itself — it lives
//!   on the [`EmbeddingService`] batcher thread, whose clone-cheap
//!   [`EmbeddingHandle`] implements [`Encoder`] for the rest of the
//!   system. Compiled in only with the `pjrt` cargo feature; the default
//!   build ships a stub whose constructor returns an error.
//!
//! Both backends produce L2-normalized `dim`-dimensional vectors and
//! agree to ~1e-4 max abs difference.

mod memo;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
mod pjrt;
mod service;
mod weights;

pub use memo::{memo_key, EmbeddingMemo, MemoConfig, MemoCounters};
pub use native::{matmul_acc_blocked, matmul_acc_naive, EncodeScratch, NativeEncoder};
pub use pjrt::PjrtEncoder;
pub use service::{BatcherConfig, EmbeddingHandle, EmbeddingService, EncoderSpec};
pub use weights::EncoderWeights;

use std::sync::Arc;
use std::time::Duration;

use crate::error::{Context, Result};
use crate::runtime::{artifacts_dir, ModelParams};

/// One encoded text plus where the embedding came from (the serving
/// layer mirrors `memo_hit` into `embed_cache_hits`/`embed_cache_misses`
/// and the response's `LatencyBreakdown::embed_cached`).
#[derive(Debug, Clone, PartialEq)]
pub struct EncodeOutcome {
    pub embedding: Vec<f32>,
    /// True when the exact-match memo tier answered (no forward pass).
    pub memo_hit: bool,
}

/// A sentence-embedding backend. Embeddings are unit-norm f32 vectors.
pub trait Encoder: Send + Sync {
    /// Embedding dimensionality.
    fn dim(&self) -> usize;
    /// Encode a batch of texts (one vector per text, unit norm).
    fn encode_batch(&self, texts: &[&str]) -> Vec<Vec<f32>>;
    /// Convenience single-text encode.
    fn encode_text(&self, text: &str) -> Vec<f32> {
        self.encode_batch(&[text]).pop().expect("one embedding")
    }
    /// [`Encoder::encode_batch`] with provenance: backends with an
    /// exact-match memo tier ([`EmbeddingMemo`]) report which texts were
    /// answered from it; `bypass_memo` skips the tier's *read* for this
    /// call (per-request benchmark escape hatch — fresh embeddings are
    /// still admitted). Backends without a memo tier fall through to
    /// `encode_batch` with every outcome marked cold.
    fn encode_batch_tracked(&self, texts: &[&str], bypass_memo: bool) -> Vec<EncodeOutcome> {
        let _ = bypass_memo;
        self.encode_batch(texts)
            .into_iter()
            .map(|embedding| EncodeOutcome { embedding, memo_hit: false })
            .collect()
    }
    /// Counters of the memo tier, if this backend has one.
    fn memo_counters(&self) -> Option<MemoCounters> {
        None
    }
    /// Flush the memo tier (admin `flush` rides through here); returns
    /// entries removed. Backends without a tier remove nothing.
    fn memo_flush(&self) -> usize {
        0
    }
    /// Hyperparameters of the underlying model.
    fn params(&self) -> &ModelParams;
}

/// Build the encoder selected by the app-level [`crate::config::Config`]
/// (`encoder_kind`): the PJRT embedding service when requested, the
/// native encoder otherwise. The native path honors the embedding
/// hot-path knobs: `embed_memo_capacity`/`embed_memo_shards` put the
/// exact-match [`EmbeddingMemo`] tier in front of the forward pass
/// (capacity 0 disables it) and `embed_workers` pins the
/// `encode_batch` pool width (0 = one per core). Shared by the
/// `semcache` and `semcached` binaries so the two stay in sync.
pub fn build_encoder(cfg: &crate::config::Config) -> Result<Arc<dyn Encoder>> {
    match cfg.encoder_kind.as_str() {
        "pjrt" => {
            let handle = EmbeddingService::spawn(
                EncoderSpec::Pjrt(artifacts_dir()),
                BatcherConfig {
                    window: Duration::from_micros(cfg.batch_window_us),
                    max_batch: cfg.max_batch,
                },
            )
            .context("starting PJRT embedding service (run `make artifacts`?)")?;
            Ok(Arc::new(handle))
        }
        _ => {
            let mut enc =
                NativeEncoder::new(ModelParams::default()).with_workers(cfg.embed_workers);
            if cfg.embed_memo_capacity > 0 {
                enc = enc
                    .with_memo(MemoConfig {
                        capacity: cfg.embed_memo_capacity,
                        shards: cfg.embed_memo_shards,
                    })
                    .context("building the embedding memo tier")?;
            }
            Ok(Arc::new(enc))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::norm;

    #[test]
    fn native_encoder_semantics() {
        let enc = NativeEncoder::minilm_sim();
        let e = enc.encode_batch(&[
            "how do i reset my password",
            "how can i reset my password",
            "what is the capital of france",
        ]);
        assert_eq!(e.len(), 3);
        for v in &e {
            assert_eq!(v.len(), enc.dim());
            assert!((norm(v) - 1.0).abs() < 1e-4, "unit norm");
        }
        let near = crate::util::dot(&e[0], &e[1]);
        let far = crate::util::dot(&e[0], &e[2]);
        assert!(near > 0.8, "paraphrase sim {near}");
        assert!(far < 0.5, "unrelated sim {far}");
        assert!(near > far + 0.2);
    }

    #[test]
    fn deterministic() {
        let enc = NativeEncoder::minilm_sim();
        let a = enc.encode_text("hello there general kenobi");
        let b = enc.encode_text("hello there general kenobi");
        assert_eq!(a, b);
    }

    #[test]
    fn empty_text_is_finite() {
        let enc = NativeEncoder::minilm_sim();
        let v = enc.encode_text("");
        assert!(v.iter().all(|x| x.is_finite()));
        assert!((norm(&v) - 1.0).abs() < 1e-4); // CLS-only sequence
    }
}
