//! Exact-match embedding memo tier: a sharded, capacity-bounded LRU in
//! front of the encoder forward pass.
//!
//! The source paper's target workload — repetitive customer-service
//! traffic with 61.6–68.8% hit rates — re-embeds a lot of text the
//! system has already embedded *verbatim*. The semantic cache still pays
//! a full transformer forward pass to discover that; this tier answers
//! repeated identical queries in a hash lookup instead (MeanCache makes
//! the same observation for client-side reuse: the embedding work is
//! where a semantic cache claws back its own overhead).
//!
//! Design:
//!
//! * **Keyed on the tokenized text**, not the raw string: queries that
//!   tokenize identically ("Reset my password?" / "reset my password")
//!   share one entry, mirroring exactly what the encoder would see. The
//!   key is the FNV-1a hash of the id sequence ([`memo_key`]); the ids
//!   themselves are stored and compared on lookup, so a 64-bit hash
//!   collision degrades to a miss-free *correct* answer, never a wrong
//!   embedding.
//! * **Sharded** — the key hash picks a shard, each shard is an
//!   independently locked LRU, so concurrent serving workers don't
//!   serialize on one mutex (the same pattern as the KV store shards).
//! * **Capacity-bounded, LRU** — each shard holds an intrusive
//!   doubly-linked recency list over a slab, giving O(1) lookup, insert,
//!   touch, and eviction (no scans), and a hard entry bound.
//! * **Observable** — lock-free hit/miss/insertion/eviction counters
//!   ([`EmbeddingMemo::counters`]); the serving layer mirrors hits and
//!   misses into `/v1/metrics` as `embed_cache_hits`/`embed_cache_misses`.
//! * **Flushable** — [`EmbeddingMemo::flush`] empties every shard
//!   (wired to `POST /v1/admin {"action": "flush"}` alongside the
//!   semantic cache flush).
//!
//! Correctness note: the encoder is deterministic, so a memoized
//! embedding is bit-identical to re-running the forward pass — the tier
//! changes latency, never results (property-tested in
//! `tests/embed_hotpath.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{bail, Result};
use crate::tokenizer::fnv1a64;

/// Memo-tier sizing knobs (config keys `embed_memo_capacity` /
/// `embed_memo_shards`; capacity 0 at the config layer disables the
/// tier entirely — a constructed memo always holds at least one entry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoConfig {
    /// Total entry bound across all shards.
    pub capacity: usize,
    /// Independently locked LRU shards.
    pub shards: usize,
}

impl Default for MemoConfig {
    fn default() -> Self {
        // 4096 entries ≈ a few MB of 384-d f32 embeddings — enough to
        // hold the hot set of the paper's repetitive workloads.
        Self { capacity: 4096, shards: 8 }
    }
}

impl MemoConfig {
    pub fn validate(&self) -> Result<()> {
        if self.capacity == 0 {
            bail!("embed memo capacity must be >= 1 (disable the tier instead of sizing it 0)");
        }
        if self.shards == 0 {
            bail!("embed memo shards must be >= 1");
        }
        Ok(())
    }
}

/// Monotonic counters of the memo tier (plus its current size).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoCounters {
    /// Lookups answered from the tier.
    pub hits: u64,
    /// Lookups that fell through to the encoder.
    pub misses: u64,
    /// Entries admitted.
    pub insertions: u64,
    /// Entries displaced by the capacity bound (flushes not included).
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
}

/// Stable key for a tokenized sequence: FNV-1a over the id bytes (the
/// same hash family the tokenizer itself uses).
pub fn memo_key(ids: &[i64]) -> u64 {
    // i64 ids are hashed via their little-endian bytes; sequences are
    // fixed-length (seq_len), so no length prefix is needed.
    let mut h = 0xcbf29ce484222325u64;
    for &id in ids {
        for b in id.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    // Defensive: keep parity with the crate hash if someone re-derives
    // it from bytes directly.
    debug_assert_eq!(h, {
        let bytes: Vec<u8> = ids.iter().flat_map(|i| i.to_le_bytes()).collect();
        fnv1a64(&bytes)
    });
    h
}

const NIL: usize = usize::MAX;

/// One resident entry in a shard's slab.
struct Slot {
    key: u64,
    ids: Box<[i64]>,
    embedding: Box<[f32]>,
    /// Recency list links (`prev` is toward most-recent).
    prev: usize,
    next: usize,
}

/// One independently locked LRU: hash→slab-slot chains plus an
/// intrusive recency list. All operations are O(1) (chains are length 1
/// except under 64-bit hash collisions).
#[derive(Default)]
struct Shard {
    /// key hash → slot indices with that hash (collision chain).
    map: HashMap<u64, Vec<usize>>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Most-recently used slot (NIL when empty).
    head: usize,
    /// Least-recently used slot (the eviction victim).
    tail: usize,
    len: usize,
}

impl Shard {
    fn new() -> Self {
        Self { head: NIL, tail: NIL, ..Self::default() }
    }

    /// Unlink `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Push `i` at the most-recent end.
    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn touch(&mut self, i: usize) {
        if self.head != i {
            self.unlink(i);
            self.push_front(i);
        }
    }

    fn find(&self, key: u64, ids: &[i64]) -> Option<usize> {
        self.map
            .get(&key)?
            .iter()
            .copied()
            .find(|&i| *self.slots[i].ids == *ids)
    }

    fn lookup(&mut self, key: u64, ids: &[i64]) -> Option<Vec<f32>> {
        let i = self.find(key, ids)?;
        self.touch(i);
        Some(self.slots[i].embedding.to_vec())
    }

    /// Admit (or refresh) an entry, reporting what happened so the
    /// tier-level counters stay exact.
    fn insert(&mut self, key: u64, ids: &[i64], embedding: &[f32], cap: usize) -> InsertOutcome {
        if let Some(i) = self.find(key, ids) {
            // Deterministic encoder ⇒ the value cannot have changed;
            // just refresh recency.
            self.touch(i);
            return InsertOutcome::Refreshed;
        }
        let mut evicted = false;
        if self.len >= cap {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL, "cap >= 1 and len >= cap implies a tail");
            self.unlink(victim);
            let vkey = self.slots[victim].key;
            if let Some(chain) = self.map.get_mut(&vkey) {
                chain.retain(|&i| i != victim);
                if chain.is_empty() {
                    self.map.remove(&vkey);
                }
            }
            self.free.push(victim);
            self.len -= 1;
            evicted = true;
        }
        let slot = Slot {
            key,
            ids: ids.into(),
            embedding: embedding.into(),
            prev: NIL,
            next: NIL,
        };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = slot;
                i
            }
            None => {
                self.slots.push(slot);
                self.slots.len() - 1
            }
        };
        self.map.entry(key).or_default().push(i);
        self.push_front(i);
        self.len += 1;
        if evicted {
            InsertOutcome::InsertedEvicting
        } else {
            InsertOutcome::Inserted
        }
    }

    fn flush(&mut self) -> usize {
        let n = self.len;
        self.map.clear();
        self.slots.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.len = 0;
        n
    }
}

enum InsertOutcome {
    Inserted,
    InsertedEvicting,
    Refreshed,
}

/// The sharded exact-match memo tier. Cheap to share (`Arc`); every
/// method takes `&self`.
pub struct EmbeddingMemo {
    shards: Box<[Mutex<Shard>]>,
    /// Per-shard entry bound (total capacity split across shards,
    /// rounded up — the tier may hold up to `shards - 1` extra entries).
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl EmbeddingMemo {
    pub fn new(cfg: MemoConfig) -> Result<Self> {
        cfg.validate()?;
        // Never let the per-shard bound hit 0 (a shard must hold >= 1).
        let shards = cfg.shards.min(cfg.capacity);
        Ok(Self {
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            per_shard_cap: cfg.capacity.div_ceil(shards),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        // High bits: the low bits already picked map buckets inside the
        // shard; using different bits decorrelates the two.
        &self.shards[(key >> 48) as usize % self.shards.len()]
    }

    /// Probe the tier for a tokenized sequence; a hit refreshes recency
    /// and returns a copy of the embedding. Records hit/miss counters.
    pub fn lookup(&self, ids: &[i64]) -> Option<Vec<f32>> {
        let key = memo_key(ids);
        let got = self.shard(key).lock().unwrap().lookup(key, ids);
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Admit a freshly computed embedding (refreshes recency if the
    /// sequence raced in since the lookup).
    pub fn insert(&self, ids: &[i64], embedding: &[f32]) {
        let key = memo_key(ids);
        let outcome =
            self.shard(key).lock().unwrap().insert(key, ids, embedding, self.per_shard_cap);
        match outcome {
            InsertOutcome::Inserted => {
                self.insertions.fetch_add(1, Ordering::Relaxed);
            }
            InsertOutcome::InsertedEvicting => {
                self.insertions.fetch_add(1, Ordering::Relaxed);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
            InsertOutcome::Refreshed => {}
        }
    }

    /// Drop every entry; returns how many were resident. Counters are
    /// monotonic and survive the flush (flushes are not evictions).
    pub fn flush(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().flush()).sum()
    }

    /// Entries currently resident across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total entry bound (per-shard bound × shards; may exceed the
    /// configured capacity by rounding, never undershoots it).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * self.shards.len()
    }

    /// Snapshot of the tier's counters and size.
    pub fn counters(&self) -> MemoCounters {
        MemoCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(tag: i64) -> Vec<i64> {
        // Distinct fixed-length sequences, like the tokenizer produces.
        vec![1, tag, tag + 1, 0, 0, 0, 0, 0]
    }

    fn emb(tag: i64) -> Vec<f32> {
        vec![tag as f32; 4]
    }

    fn single_shard(cap: usize) -> EmbeddingMemo {
        EmbeddingMemo::new(MemoConfig { capacity: cap, shards: 1 }).unwrap()
    }

    #[test]
    fn config_validation() {
        assert!(MemoConfig::default().validate().is_ok());
        assert!(MemoConfig { capacity: 0, shards: 1 }.validate().is_err());
        assert!(MemoConfig { capacity: 8, shards: 0 }.validate().is_err());
        // More shards than capacity collapses to capacity-many shards,
        // each holding one entry — still a valid bounded tier.
        let m = EmbeddingMemo::new(MemoConfig { capacity: 2, shards: 16 }).unwrap();
        assert_eq!(m.capacity(), 2);
    }

    #[test]
    fn miss_then_hit_roundtrip() {
        let m = single_shard(8);
        assert!(m.lookup(&ids(1)).is_none());
        m.insert(&ids(1), &emb(1));
        assert_eq!(m.lookup(&ids(1)).unwrap(), emb(1));
        let c = m.counters();
        assert_eq!((c.hits, c.misses, c.insertions, c.evictions, c.entries), (1, 1, 1, 0, 1));
    }

    #[test]
    fn lru_eviction_order_respects_touches() {
        let m = single_shard(3);
        for t in [1, 2, 3] {
            m.insert(&ids(t), &emb(t));
        }
        // Touch 1 so 2 becomes the LRU victim.
        assert!(m.lookup(&ids(1)).is_some());
        m.insert(&ids(4), &emb(4));
        assert_eq!(m.len(), 3, "capacity bound holds");
        assert!(m.lookup(&ids(2)).is_none(), "LRU entry evicted");
        for t in [1, 3, 4] {
            assert!(m.lookup(&ids(t)).is_some(), "entry {t} survived");
        }
        assert_eq!(m.counters().evictions, 1);
    }

    #[test]
    fn reinserting_refreshes_instead_of_duplicating() {
        let m = single_shard(2);
        m.insert(&ids(1), &emb(1));
        m.insert(&ids(2), &emb(2));
        // Re-insert 1 (refresh, no insertion/eviction), then admit 3:
        // the victim must be 2, not the refreshed 1.
        m.insert(&ids(1), &emb(1));
        m.insert(&ids(3), &emb(3));
        assert!(m.lookup(&ids(1)).is_some());
        assert!(m.lookup(&ids(2)).is_none());
        let c = m.counters();
        assert_eq!(c.insertions, 3, "refresh is not an insertion");
        assert_eq!(c.evictions, 1);
        assert_eq!(c.entries, 2);
    }

    #[test]
    fn flush_empties_and_preserves_monotonic_counters() {
        let m = single_shard(8);
        for t in 0..5 {
            m.insert(&ids(t), &emb(t));
        }
        let before = m.counters();
        assert_eq!(m.flush(), 5);
        assert!(m.is_empty());
        assert!(m.lookup(&ids(0)).is_none(), "flushed entries are gone");
        let after = m.counters();
        assert_eq!(after.insertions, before.insertions);
        assert_eq!(after.evictions, before.evictions, "flush is not an eviction");
        assert_eq!(after.entries, 0);
        // The tier keeps working after a flush.
        m.insert(&ids(9), &emb(9));
        assert!(m.lookup(&ids(9)).is_some());
    }

    #[test]
    fn counter_consistency_under_concurrent_traffic() {
        let m = EmbeddingMemo::new(MemoConfig { capacity: 64, shards: 4 }).unwrap();
        std::thread::scope(|scope| {
            for t in 0..4i64 {
                let m = &m;
                scope.spawn(move || {
                    for round in 0..200i64 {
                        let tag = t * 50 + round % 100;
                        if m.lookup(&ids(tag)).is_none() {
                            m.insert(&ids(tag), &emb(tag));
                        }
                    }
                });
            }
        });
        let c = m.counters();
        assert_eq!(c.hits + c.misses, 800, "every lookup is a hit or a miss");
        assert!(c.entries <= m.capacity(), "capacity bound holds under races");
        assert!(
            c.insertions >= c.evictions + c.entries as u64,
            "insertions account for residents + evictions (refreshes excluded): {c:?}"
        );
        assert!(c.hits > 0, "repeated tags must hit");
    }

    #[test]
    fn hash_collisions_compare_full_ids() {
        // Force both sequences into one shard and assert the chain
        // disambiguates by ids even when we can't easily fabricate a
        // 64-bit collision: distinct ids must never alias.
        let m = single_shard(8);
        m.insert(&ids(1), &emb(1));
        m.insert(&ids(2), &emb(2));
        assert_eq!(m.lookup(&ids(1)).unwrap(), emb(1));
        assert_eq!(m.lookup(&ids(2)).unwrap(), emb(2));
        // Different length sequences with shared prefix stay distinct.
        let short = vec![1i64, 7];
        let long = vec![1i64, 7, 0];
        m.insert(&short, &emb(3));
        assert!(m.lookup(&long).is_none());
    }

    #[test]
    fn memo_key_matches_fnv_over_le_bytes() {
        let seq = vec![1i64, -42, 1 << 40];
        let bytes: Vec<u8> = seq.iter().flat_map(|i| i.to_le_bytes()).collect();
        assert_eq!(memo_key(&seq), fnv1a64(&bytes));
    }
}
