//! Embedding service: the dynamic micro-batcher that owns the encoder.
//!
//! PJRT objects are `Rc`-based (!Send), so a dedicated worker thread owns
//! the `Runtime` + `PjrtEncoder` (or a `NativeEncoder`) and services
//! encode requests from an mpsc queue. Requests arriving within the
//! batching window are coalesced up to the largest compiled batch size —
//! this is what turns the paper's per-query embedding call into efficient
//! batched inference on the serving path (DESIGN.md §4 `embedding/`).
//!
//! The cheap-to-clone [`EmbeddingHandle`] implements [`Encoder`] and can
//! be shared across coordinator workers. Submission is lock-free: every
//! handle owns its own clone of the queue sender (`mpsc::Sender` is
//! `Clone`), so concurrent coordinator workers never serialize on a
//! shared mutex just to enqueue (the seed wrapped one sender in
//! `Arc<Mutex<..>>`, making every submit a lock acquisition).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::Result;

use crate::runtime::ModelParams;

use super::{EncodeOutcome, Encoder, NativeEncoder, PjrtEncoder};

/// Which backend the worker thread should build.
#[derive(Debug, Clone)]
pub enum EncoderSpec {
    /// Pure-Rust forward pass (no artifacts needed).
    Native(ModelParams),
    /// AOT artifacts via PJRT from this directory.
    Pjrt(std::path::PathBuf),
}

struct EncodeRequest {
    texts: Vec<String>,
    /// Skip the memo-tier read for this request (benchmark escape hatch).
    bypass_memo: bool,
    reply: mpsc::SyncSender<Vec<EncodeOutcome>>,
}

/// Shareable, Send+Sync handle to the batcher thread. Cloning clones the
/// queue sender — submission never takes a lock.
#[derive(Clone)]
pub struct EmbeddingHandle {
    tx: mpsc::Sender<EncodeRequest>,
    dim: usize,
    params: ModelParams,
}

/// The service: spawn once, clone handles freely. Dropping the last
/// handle shuts the worker down (sender disconnect).
pub struct EmbeddingService;

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// How long to wait for more requests after the first arrives.
    pub window: Duration,
    /// Hard cap on coalesced batch size.
    pub max_batch: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        // max_batch 8, not 32: the interpret-mode Pallas attention lowers
        // to a serialized grid loop on CPU-PJRT, so large batches do not
        // amortize (measured in §Perf: b=32 costs ~3x more per query than
        // b=1..8). On a real TPU the grid runs parallel and 32 is right.
        Self { window: Duration::from_micros(200), max_batch: 8 }
    }
}

impl EmbeddingService {
    /// Spawn the worker; blocks until the backend is initialized so
    /// startup failures (missing artifacts, bad manifest) surface here.
    pub fn spawn(spec: EncoderSpec, cfg: BatcherConfig) -> Result<EmbeddingHandle> {
        let (tx, rx) = mpsc::channel::<EncodeRequest>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<ModelParams>>(1);
        std::thread::Builder::new()
            .name("embed-batcher".into())
            .spawn(move || worker(spec, cfg, rx, ready_tx))
            .expect("spawn embed-batcher");
        let params = ready_rx.recv().expect("batcher init reply")?;
        Ok(EmbeddingHandle { tx, dim: params.dim, params })
    }
}

fn worker(
    spec: EncoderSpec,
    cfg: BatcherConfig,
    rx: mpsc::Receiver<EncodeRequest>,
    ready: mpsc::SyncSender<Result<ModelParams>>,
) {
    // Build the backend on this thread (PJRT is thread-bound).
    enum Backend {
        Native(NativeEncoder),
        Pjrt(PjrtEncoder),
    }
    let backend = match &spec {
        EncoderSpec::Native(p) => Ok(Backend::Native(NativeEncoder::new(p.clone()))),
        EncoderSpec::Pjrt(dir) => PjrtEncoder::from_artifacts_dir(dir).map(Backend::Pjrt),
    };
    let backend = match backend {
        Ok(b) => {
            let params = match &b {
                Backend::Native(n) => n.weights().params.clone(),
                Backend::Pjrt(p) => p.params().clone(),
            };
            let _ = ready.send(Ok(params));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let encode = |texts: &[&str], bypass: bool| -> Vec<EncodeOutcome> {
        match &backend {
            Backend::Native(n) => n.encode_batch_tracked(texts, bypass),
            Backend::Pjrt(p) => p
                .encode_batch(texts)
                .expect("PJRT encode")
                .into_iter()
                .map(|embedding| EncodeOutcome { embedding, memo_hit: false })
                .collect(),
        }
    };

    // Batching loop: block for the first request, then drain the queue
    // within the window up to max_batch texts.
    while let Ok(first) = rx.recv() {
        let mut batch = vec![first];
        let mut n_texts = batch[0].texts.len();
        let deadline = Instant::now() + cfg.window;
        while n_texts < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => {
                    n_texts += req.texts.len();
                    batch.push(req);
                }
                Err(_) => break,
            }
        }
        // Encode the union (bypass requests split into their own union
        // so one caller's benchmark flag never disables the memo read
        // for everyone coalesced with it), split replies per request.
        for wanted_bypass in [false, true] {
            let texts: Vec<&str> = batch
                .iter()
                .filter(|r| r.bypass_memo == wanted_bypass)
                .flat_map(|r| r.texts.iter().map(|s| s.as_str()))
                .collect();
            if texts.is_empty() {
                continue;
            }
            let mut outcomes = encode(&texts, wanted_bypass).into_iter();
            for req in batch.iter().filter(|r| r.bypass_memo == wanted_bypass) {
                let out: Vec<EncodeOutcome> =
                    (&mut outcomes).take(req.texts.len()).collect();
                let _ = req.reply.send(out); // receiver may have given up; fine
            }
        }
    }
}

impl EmbeddingHandle {
    fn submit(&self, texts: &[&str], bypass_memo: bool) -> Vec<EncodeOutcome> {
        if texts.is_empty() {
            return Vec::new();
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        let req = EncodeRequest {
            texts: texts.iter().map(|s| s.to_string()).collect(),
            bypass_memo,
            reply: reply_tx,
        };
        self.tx.send(req).expect("embedding worker alive");
        reply_rx.recv().expect("embedding reply")
    }
}

impl Encoder for EmbeddingHandle {
    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_batch(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        self.submit(texts, false).into_iter().map(|o| o.embedding).collect()
    }

    fn encode_batch_tracked(&self, texts: &[&str], bypass_memo: bool) -> Vec<EncodeOutcome> {
        self.submit(texts, bypass_memo)
    }

    fn params(&self) -> &ModelParams {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::dot;

    fn small_params() -> ModelParams {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 512;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
        p
    }

    #[test]
    fn service_roundtrip_native() {
        let h = EmbeddingService::spawn(
            EncoderSpec::Native(small_params()),
            BatcherConfig::default(),
        )
        .unwrap();
        let out = h.encode_batch(&["hello world", "hello world", "different query"]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[1]);
        assert!(dot(&out[0], &out[2]) < 0.999);
    }

    #[test]
    fn concurrent_callers_get_their_own_replies() {
        let h = EmbeddingService::spawn(
            EncoderSpec::Native(small_params()),
            BatcherConfig { window: Duration::from_millis(5), max_batch: 16 },
        )
        .unwrap();
        let mut join = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            join.push(std::thread::spawn(move || {
                let text = format!("query number {t}");
                let mine = h.encode_text(&text);
                let again = h.encode_text(&text);
                assert_eq!(mine, again, "caller {t} got someone else's embedding");
            }));
        }
        for j in join {
            j.join().unwrap();
        }
    }

    #[test]
    fn empty_batch_short_circuits() {
        let h = EmbeddingService::spawn(
            EncoderSpec::Native(small_params()),
            BatcherConfig::default(),
        )
        .unwrap();
        assert!(h.encode_batch(&[]).is_empty());
    }

    #[test]
    fn matches_direct_native_encoder() {
        let p = small_params();
        let h = EmbeddingService::spawn(EncoderSpec::Native(p.clone()), BatcherConfig::default())
            .unwrap();
        let direct = NativeEncoder::new(p);
        let a = h.encode_text("the quick brown fox");
        let b = direct.encode_text("the quick brown fox");
        assert_eq!(a, b);
    }

    #[test]
    fn tracked_and_bypass_flow_through_the_worker() {
        let h = EmbeddingService::spawn(
            EncoderSpec::Native(small_params()),
            BatcherConfig::default(),
        )
        .unwrap();
        // The service's native backend has no memo tier attached, so
        // everything reports cold — the point is the plumbing round-trips
        // per-request flags without mixing unions.
        let a = h.encode_batch_tracked(&["one", "two"], false);
        let b = h.encode_batch_tracked(&["one"], true);
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 1);
        assert!(a.iter().chain(&b).all(|o| !o.memo_hit));
        assert_eq!(a[0].embedding, b[0].embedding, "bypass never changes values");
    }
}
