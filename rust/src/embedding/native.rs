//! Pure-Rust forward pass of the encoder — the artifact-free twin of the
//! L2 JAX model. Formula-for-formula identical to `python/compile/model.py`
//! (parameter-free LayerNorm eps 1e-6, tanh-approximate GELU, masked mean
//! pool, zero-safe L2 normalize); `rust/tests/parity.rs` asserts the two
//! agree through PJRT to ~1e-4.
//!
//! ## Hot-path layout (ISSUE 4)
//!
//! The forward pass is the most expensive compute in the system — every
//! cache query pays it (unless the [`super::EmbeddingMemo`] tier answers
//! first), so the encode path is engineered to allocate nothing after
//! warm-up and to use every core the caller hands it:
//!
//! * **[`EncodeScratch`] arena** — all intermediate buffers (`x`, the
//!   LayerNorm output, q/k/v, attention context, the FFN hidden, the
//!   attention score row, the token mask, and the pooled output row)
//!   live in one reusable arena. [`NativeEncoder::encode_ids_into`] is
//!   fully zero-alloc; [`NativeEncoder::encode_ids`] keeps its seed
//!   signature by borrowing a thread-local arena and allocates only the
//!   returned vector. The seed implementation allocated 8 buffers plus
//!   a full `s×d` `x.clone()` per call.
//! * **Parallel batches** — [`Encoder::encode_batch`] splits the batch
//!   across a scoped worker pool ([`NativeEncoder::encode_batch_with_workers`]),
//!   one arena per worker. Sequences are independent, so the output is
//!   bit-identical to the sequential loop for every worker count
//!   (property-tested in `tests/embed_hotpath.rs` against a naive
//!   re-implementation of the seed forward pass).
//! * **Memo tier** — an optional exact-match LRU
//!   ([`NativeEncoder::with_memo`]) answers repeated identical queries
//!   (same tokenized ids) without running the forward pass at all.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::runtime::ModelParams;
use crate::tokenizer::{Tokenizer, PAD_ID};
use crate::util::dot;

use super::memo::{EmbeddingMemo, MemoConfig, MemoCounters};
use super::weights::EncoderWeights;
use super::{EncodeOutcome, Encoder};

/// CPU-native encoder: tokenizer + generated weights + forward pass.
pub struct NativeEncoder {
    weights: EncoderWeights,
    tokenizer: Tokenizer,
    /// Exact-match embedding memo tier (None = disabled).
    memo: Option<Arc<EmbeddingMemo>>,
    /// Worker-pool width for `encode_batch` (0 = one per available core).
    workers: usize,
    /// Batch encodes currently in flight on this encoder. The requested
    /// pool width is divided by this, so N server workers batch-encoding
    /// concurrently share the cores instead of each spawning a full
    /// pool (N×cores threads of matmul contention).
    active_encodes: AtomicUsize,
}

const LN_EPS: f32 = 1e-6;

/// Reusable arena for one encoder forward pass: every intermediate
/// buffer `encode_ids` needs, sized once and reused across calls so the
/// encode hot path allocates nothing after warm-up. One arena serves one
/// thread at a time; the batch pipeline gives each worker its own.
#[derive(Default)]
pub struct EncodeScratch {
    /// Residual stream, (s, d).
    x: Vec<f32>,
    /// LayerNorm output (attention/FFN input), (s, d).
    hbuf: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context, (s, d).
    ctx: Vec<f32>,
    /// FFN hidden activations, (s, h).
    ffn_h: Vec<f32>,
    /// Attention score row, (s,).
    scores: Vec<f32>,
    /// Token mask, (s,).
    mask: Vec<f32>,
}

impl EncodeScratch {
    /// An arena pre-sized for `params` (it also grows on demand, so
    /// `EncodeScratch::default()` works too).
    pub fn for_params(params: &ModelParams) -> Self {
        let mut s = Self::default();
        s.ensure(params.seq_len, params.dim, params.hidden);
        s
    }

    /// Grow every buffer to fit an (s, d, h) forward pass. No-op (and
    /// alloc-free) once the arena has seen these dimensions.
    fn ensure(&mut self, s: usize, d: usize, h: usize) {
        grow(&mut self.x, s * d);
        grow(&mut self.hbuf, s * d);
        grow(&mut self.q, s * d);
        grow(&mut self.k, s * d);
        grow(&mut self.v, s * d);
        grow(&mut self.ctx, s * d);
        grow(&mut self.ffn_h, s * h);
        grow(&mut self.scores, s);
        grow(&mut self.mask, s);
    }
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

thread_local! {
    /// Arena backing the allocating-signature [`NativeEncoder::encode_ids`]
    /// (and single-text [`Encoder::encode_text`] calls): after the first
    /// encode on a thread, only the returned vector is allocated.
    static TLS_SCRATCH: RefCell<EncodeScratch> = RefCell::new(EncodeScratch::default());
}

impl NativeEncoder {
    pub fn new(params: ModelParams) -> Self {
        let tokenizer = Tokenizer::new(params.vocab_size, params.seq_len);
        Self {
            weights: EncoderWeights::generate(&params),
            tokenizer,
            memo: None,
            workers: 0,
            active_encodes: AtomicUsize::new(0),
        }
    }

    /// The default MiniLM-geometry simulation encoder (DESIGN.md §3).
    pub fn minilm_sim() -> Self {
        Self::new(ModelParams::default())
    }

    /// Put an exact-match memo tier ([`EmbeddingMemo`]) in front of the
    /// forward pass: repeated identical queries (same tokenized ids)
    /// are answered from the LRU without encoding.
    pub fn with_memo(mut self, cfg: MemoConfig) -> crate::error::Result<Self> {
        self.memo = Some(Arc::new(EmbeddingMemo::new(cfg)?));
        Ok(self)
    }

    /// Set the `encode_batch` worker-pool width (0 = one worker per
    /// available core, the default).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// The memo tier, if one was attached.
    pub fn memo(&self) -> Option<&EmbeddingMemo> {
        self.memo.as_deref()
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn weights(&self) -> &EncoderWeights {
        &self.weights
    }

    /// Resolved `encode_batch` pool width.
    fn pool_width(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// Encode pre-tokenized ids (one sequence) to a unit vector.
    ///
    /// Same math as the seed implementation, but all intermediates live
    /// in a thread-local [`EncodeScratch`]: after the first call on a
    /// thread only the returned vector is allocated.
    pub fn encode_ids(&self, ids: &[i64]) -> Vec<f32> {
        TLS_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let mut out = vec![0.0f32; self.weights.params.dim];
            self.encode_ids_into(ids, &mut scratch, &mut out);
            out
        })
    }

    /// [`NativeEncoder::encode_ids`] with caller-owned scratch — the
    /// zero-allocation encode path (`out.len()` must equal `dim`).
    /// Bit-identical to the seed `encode_ids`: identical formulas in
    /// identical floating-point operation order, only the buffer
    /// lifetimes changed.
    pub fn encode_ids_into(&self, ids: &[i64], scratch: &mut EncodeScratch, out: &mut [f32]) {
        let p = &self.weights.params;
        assert_eq!(ids.len(), p.seq_len);
        assert_eq!(out.len(), p.dim);
        let (s, d, h) = (p.seq_len, p.dim, p.hidden);
        let heads = p.heads;
        let dh = d / heads;
        scratch.ensure(s, d, h);
        let EncodeScratch { x, hbuf, q, k, v, ctx, ffn_h, scores, mask } = scratch;
        // The arena may be larger than this model needs (it grows
        // monotonically across models on the same thread); slice to size.
        let x = &mut x[..s * d];
        let hbuf = &mut hbuf[..s * d];
        let q = &mut q[..s * d];
        let k = &mut k[..s * d];
        let v = &mut v[..s * d];
        let ctx = &mut ctx[..s * d];
        let ffn_h = &mut ffn_h[..s * h];
        let scores = &mut scores[..s];
        let mask = &mut mask[..s];

        // x = embed[tokens] + pos
        for (i, &t) in ids.iter().enumerate() {
            let row = self.weights.embed_row(t);
            let pos = &self.weights.pos[i * d..(i + 1) * d];
            for j in 0..d {
                x[i * d + j] = row[j] + pos[j];
            }
        }
        for (m, &t) in mask.iter_mut().zip(ids.iter()) {
            *m = if t == PAD_ID { 0.0 } else { 1.0 };
        }

        for l in 0..p.layers {
            // --- attention block: x += (attn(LN(x))) @ wo
            layer_norm_rows(x, hbuf, s, d);
            let wq = EncoderWeights::layer(&self.weights.wq, l, d, d);
            let wk = EncoderWeights::layer(&self.weights.wk, l, d, d);
            let wv = EncoderWeights::layer(&self.weights.wv, l, d, d);
            let wo = EncoderWeights::layer(&self.weights.wo, l, d, d);
            matmul(hbuf, wq, q, s, d, d);
            matmul(hbuf, wk, k, s, d, d);
            matmul(hbuf, wv, v, s, d, d);
            attention(q, k, v, mask, ctx, scores, s, heads, dh);
            matmul_add(ctx, wo, x, s, d, d);

            // --- FFN block: x += gelu(LN(x) @ w1) @ w2
            layer_norm_rows(x, hbuf, s, d);
            let w1 = EncoderWeights::layer(&self.weights.w1, l, d, h);
            let w2 = EncoderWeights::layer(&self.weights.w2, l, h, d);
            matmul(hbuf, w1, ffn_h, s, d, h);
            for e in ffn_h.iter_mut() {
                *e = gelu(*e);
            }
            matmul_add(ffn_h, w2, x, s, h, d);
        }

        // Final LayerNorm into the scratch LN buffer (the seed cloned
        // the full s×d residual stream here just to alias-free the call).
        layer_norm_rows(x, hbuf, s, d);

        // Masked mean pool + L2 normalize (zero-safe).
        let denom = mask.iter().sum::<f32>().max(1.0);
        let pooled = out;
        pooled.fill(0.0);
        for i in 0..s {
            if mask[i] > 0.0 {
                for j in 0..d {
                    pooled[j] += hbuf[i * d + j];
                }
            }
        }
        for e in pooled.iter_mut() {
            *e /= denom;
        }
        let n = {
            let p: &[f32] = pooled;
            dot(p, p).sqrt().max(1e-12)
        };
        for e in pooled.iter_mut() {
            *e /= n;
        }
    }

    /// Encode a batch across `workers` scoped threads (one
    /// [`EncodeScratch`] arena per worker). Sequences are encoded
    /// independently, so the result is bit-identical to the sequential
    /// loop for every pool width. Memoization is *not* consulted here —
    /// this is the raw forward-pass path (the memo sits in
    /// [`Encoder::encode_batch_tracked`]).
    pub fn encode_batch_with_workers(&self, texts: &[&str], workers: usize) -> Vec<Vec<f32>> {
        let ids: Vec<Vec<i64>> = texts.iter().map(|t| self.tokenizer.encode(t)).collect();
        let id_slices: Vec<&[i64]> = ids.iter().map(|v| v.as_slice()).collect();
        self.encode_ids_batch(&id_slices, workers)
    }

    /// The forward pass over pre-tokenized sequences, parallelized
    /// across up to `workers` threads. `workers` is a *cap*: concurrent
    /// batch encodes on the same encoder split the requested width
    /// between them (`active_encodes`), so the serving pipeline's own
    /// worker pool doesn't multiply into cores×workers encode threads.
    fn encode_ids_batch(&self, ids: &[&[i64]], workers: usize) -> Vec<Vec<f32>> {
        let n = ids.len();
        if n == 0 {
            return Vec::new();
        }
        let d = self.weights.params.dim;
        let active = self.active_encodes.fetch_add(1, Ordering::Relaxed) + 1;
        // Decrement on every exit path (including a panicking encode).
        struct ActiveGuard<'a>(&'a AtomicUsize);
        impl Drop for ActiveGuard<'_> {
            fn drop(&mut self) {
                self.0.fetch_sub(1, Ordering::Relaxed);
            }
        }
        let _guard = ActiveGuard(&self.active_encodes);
        let workers = (workers.max(1) / active).max(1).min(n);
        if workers == 1 {
            // No pool: the sequential fast path (also the single-text
            // serving shape, where spawning would only add latency).
            // Uses the thread-local arena, so a cold serve() encode
            // allocates nothing but its output vector after warm-up.
            return TLS_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                ids.iter()
                    .map(|&seq| {
                        let mut out = vec![0.0f32; d];
                        self.encode_ids_into(seq, &mut scratch, &mut out);
                        out
                    })
                    .collect()
            });
        }
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); n];
        // Static contiguous partitioning: every sequence costs the same
        // fixed (s, d, h) forward pass regardless of text length, so
        // equal-size chunks are load-balanced by construction and each
        // worker owns a disjoint `&mut` slice of the output (no locks).
        let chunk = n.div_ceil(workers);
        std::thread::scope(|scope| {
            for (w, out_chunk) in out.chunks_mut(chunk).enumerate() {
                let ids = &ids;
                scope.spawn(move || {
                    let mut scratch = EncodeScratch::for_params(&self.weights.params);
                    for (j, slot) in out_chunk.iter_mut().enumerate() {
                        *slot = vec![0.0f32; d];
                        self.encode_ids_into(ids[w * chunk + j], &mut scratch, slot);
                    }
                });
            }
        });
        out
    }
}

impl Encoder for NativeEncoder {
    fn dim(&self) -> usize {
        self.weights.params.dim
    }

    fn encode_batch(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        self.encode_batch_tracked(texts, false).into_iter().map(|o| o.embedding).collect()
    }

    /// The serving encode path: memo tier in front of the parallel
    /// forward pass. Per text: tokenize, probe the memo (unless
    /// `bypass_memo`), batch-encode only the misses across the worker
    /// pool, then admit the fresh embeddings.
    fn encode_batch_tracked(&self, texts: &[&str], bypass_memo: bool) -> Vec<EncodeOutcome> {
        let ids: Vec<Vec<i64>> = texts.iter().map(|t| self.tokenizer.encode(t)).collect();
        let memo = if bypass_memo { None } else { self.memo.as_deref() };
        let mut outcomes: Vec<Option<EncodeOutcome>> = match memo {
            Some(m) => ids
                .iter()
                .map(|seq| {
                    m.lookup(seq)
                        .map(|embedding| EncodeOutcome { embedding, memo_hit: true })
                })
                .collect(),
            None => vec![None; ids.len()],
        };
        let miss_idx: Vec<usize> =
            (0..ids.len()).filter(|&i| outcomes[i].is_none()).collect();
        if !miss_idx.is_empty() {
            let miss_ids: Vec<&[i64]> =
                miss_idx.iter().map(|&i| ids[i].as_slice()).collect();
            let encoded = self.encode_ids_batch(&miss_ids, self.pool_width());
            for (&i, embedding) in miss_idx.iter().zip(encoded) {
                // Admit via `self.memo`, not the bypass-filtered `memo`
                // binding: a bypass skips the *read* (benchmarking the
                // cold path) but still publishes the fresh embedding for
                // the real traffic behind it.
                if let Some(m) = self.memo.as_deref() {
                    m.insert(&ids[i], &embedding);
                }
                outcomes[i] = Some(EncodeOutcome { embedding, memo_hit: false });
            }
        }
        outcomes.into_iter().map(|o| o.expect("every text resolved")).collect()
    }

    fn memo_counters(&self) -> Option<MemoCounters> {
        self.memo.as_deref().map(EmbeddingMemo::counters)
    }

    fn memo_flush(&self) -> usize {
        self.memo.as_deref().map(EmbeddingMemo::flush).unwrap_or(0)
    }

    fn params(&self) -> &ModelParams {
        &self.weights.params
    }
}

/// tanh-approximate GELU (matches `jax` model twin exactly in formula).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise parameter-free LayerNorm: out[i] = (x[i]-mu)/sqrt(var+eps).
fn layer_norm_rows(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..cols {
            out[r * cols + c] = (row[c] - mu) * inv;
        }
    }
}

/// out = a (rows×inner) @ b (inner×cols), both row-major.
fn matmul(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, rows, inner, cols);
}

/// out += a @ b (residual add fused into the accumulation).
fn matmul_add(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    matmul_acc(a, b, out, rows, inner, cols);
}

/// Rows per register tile of the blocked kernel.
const MR: usize = 4;
/// Columns per register tile (one cache-line-friendly strip; two SSE /
/// one AVX vector per row, so MR×NR accumulators fit the register file).
const NR: usize = 8;

/// out += a @ b, dispatching between the blocked kernel and the seed
/// scalar loop (`SEMCACHE_SCALAR_KERNELS=1` forces the latter so CI
/// exercises both). Both orders are bit-identical: see
/// `matmul_acc_blocked`.
#[inline]
fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(out.len(), rows * cols);
    if crate::util::scalar_kernels_forced() {
        matmul_acc_naive(a, b, out, rows, inner, cols);
    } else {
        matmul_acc_blocked(a, b, out, rows, inner, cols);
    }
}

/// The seed kernel: classic ikj order, `b` rows streaming, the inner j
/// loop autovectorized. Kept verbatim as the scalar reference arm and
/// the bit-compatibility oracle — every output element accumulates its
/// k terms in strictly ascending k order. Public for the kernel-ratio
/// arm of `bench_embed_throughput`; serving code goes through the
/// [`matmul_acc`] dispatcher.
pub fn matmul_acc_naive(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    inner: usize,
    cols: usize,
) {
    for i in 0..rows {
        let a_row = &a[i * inner..(i + 1) * inner];
        let o_row = &mut out[i * cols..(i + 1) * cols];
        for (kk, &aik) in a_row.iter().enumerate() {
            let b_row = &b[kk * cols..(kk + 1) * cols];
            for j in 0..cols {
                o_row[j] += aik * b_row[j];
            }
        }
    }
}

/// Register-tiled kernel: an MR×NR tile of `out` is held in local
/// accumulators while k sweeps the full inner dimension, so each `b`
/// row strip is reused across MR rows of `a` and `out` is loaded and
/// stored once per tile instead of once per k (the ikj loop's
/// bandwidth bottleneck). The independent per-tile accumulators
/// autovectorize the same way `util::vecmath::dot`'s 8-lane array does.
///
/// Bit-compatible with `matmul_acc_naive` by construction: floating-
/// point addition order only changes *per output element* if the k
/// order changes, and here every element still accumulates k = 0..inner
/// in ascending order onto its prior value — the tiling only reorders
/// *across* independent output elements. (This is also why the kernel
/// must never be "improved" with a split-k reduction or FMA
/// contraction: both change per-element rounding. The parity property
/// tests in `tests/embed_hotpath.rs` and below pin this.) Public for
/// the kernel-ratio arm of `bench_embed_throughput`.
pub fn matmul_acc_blocked(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    inner: usize,
    cols: usize,
) {
    let mut i = 0;
    while i + MR <= rows {
        let a0 = &a[i * inner..(i + 1) * inner];
        let a1 = &a[(i + 1) * inner..(i + 2) * inner];
        let a2 = &a[(i + 2) * inner..(i + 3) * inner];
        let a3 = &a[(i + 3) * inner..(i + 4) * inner];
        let mut j = 0;
        while j + NR <= cols {
            let mut acc = [[0.0f32; NR]; MR];
            for (r, acc_row) in acc.iter_mut().enumerate() {
                acc_row.copy_from_slice(&out[(i + r) * cols + j..(i + r) * cols + j + NR]);
            }
            for k in 0..inner {
                let b_row = &b[k * cols + j..k * cols + j + NR];
                let aik = [a0[k], a1[k], a2[k], a3[k]];
                for r in 0..MR {
                    for c in 0..NR {
                        acc[r][c] += aik[r] * b_row[c];
                    }
                }
            }
            for (r, acc_row) in acc.iter().enumerate() {
                out[(i + r) * cols + j..(i + r) * cols + j + NR].copy_from_slice(acc_row);
            }
            j += NR;
        }
        // Column remainder of the MR-row band: plain k-ascending loop.
        if j < cols {
            for r in 0..MR {
                let a_row = &a[(i + r) * inner..(i + r + 1) * inner];
                let o_row = &mut out[(i + r) * cols + j..(i + r + 1) * cols];
                for (kk, &aik) in a_row.iter().enumerate() {
                    let b_row = &b[kk * cols + j..(kk + 1) * cols];
                    for (o, &bv) in o_row.iter_mut().zip(b_row) {
                        *o += aik * bv;
                    }
                }
            }
        }
        i += MR;
    }
    // Row remainder: the seed loop over the leftover rows.
    if i < rows {
        matmul_acc_naive(
            &a[i * inner..],
            b,
            &mut out[i * cols..],
            rows - i,
            inner,
            cols,
        );
    }
}

/// Multi-head masked attention over row-major (S, D) q/k/v. `scores` is
/// the caller's (S,) scratch row (part of the [`EncodeScratch`] arena).
#[allow(clippy::too_many_arguments)]
fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    out: &mut [f32],
    scores: &mut [f32],
    s: usize,
    heads: usize,
    dh: usize,
) {
    let d = heads * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    for hd in 0..heads {
        let off = hd * dh;
        for i in 0..s {
            let qi = &q[i * d + off..i * d + off + dh];
            let mut max = f32::MIN;
            for j in 0..s {
                let kj = &k[j * d + off..j * d + off + dh];
                let mut sc = dot(qi, kj) * scale;
                sc += (1.0 - mask[j]) * -1e9;
                scores[j] = sc;
                if sc > max {
                    max = sc;
                }
            }
            let mut sum = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                sum += *sc;
            }
            let inv = 1.0 / sum;
            let o = &mut out[i * d + off..i * d + off + dh];
            o.fill(0.0);
            for j in 0..s {
                let w = scores[j] * inv;
                let vj = &v[j * d + off..j * d + off + dh];
                for c in 0..dh {
                    o[c] += w * vj[c];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3x2
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 3, 2);
        assert_eq!(out, [58.0, 64.0, 139.0, 154.0]);
        // matmul_add accumulates.
        matmul_add(&a, &b, &mut out, 2, 3, 2);
        assert_eq!(out, [116.0, 128.0, 278.0, 308.0]);
    }

    #[test]
    fn prop_blocked_matmul_bit_identical_to_naive() {
        // Scalar-parity property in the tests/embed_hotpath.rs
        // convention: the blocked tile kernel must be *bitwise* equal
        // to the seed ikj loop for arbitrary shapes (including tile
        // edges: rows % MR != 0, cols % NR != 0) and arbitrary
        // pre-existing `out` contents (the accumulate contract).
        use crate::testutil::{prop_check, PropConfig};
        prop_check(PropConfig { cases: 48, ..Default::default() }, "blocked-matmul-parity", |g| {
            let rows = g.usize_in(1, 3 * MR + 1);
            let inner = g.usize_in(1, 40);
            let cols = g.usize_in(1, 3 * NR + 3);
            let a = g.vec_f32(rows * inner, -2.0, 2.0);
            let b = g.vec_f32(inner * cols, -2.0, 2.0);
            let seed_out = g.vec_f32(rows * cols, -1.0, 1.0);
            let mut blocked = seed_out.clone();
            let mut naive = seed_out;
            matmul_acc_blocked(&a, &b, &mut blocked, rows, inner, cols);
            matmul_acc_naive(&a, &b, &mut naive, rows, inner, cols);
            for (i, (x, y)) in blocked.iter().zip(&naive).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!(
                        "{rows}x{inner}x{cols}: element {i} diverged ({x:?} vs {y:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let mut out = [0.0f32; 8];
        layer_norm_rows(&x, &mut out, 2, 4);
        for r in 0..2 {
            let row = &out[r * 4..(r + 1) * 4];
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
    }

    #[test]
    fn attention_uniform_when_keys_equal() {
        // All keys identical -> probs uniform over unmasked -> out = mean(v).
        let s = 4;
        let (heads, dh) = (1, 2);
        let q = vec![1.0f32; s * 2];
        let k = vec![1.0f32; s * 2];
        let v: Vec<f32> = (0..s * 2).map(|i| i as f32).collect();
        let mask = vec![1.0f32, 1.0, 1.0, 0.0]; // last is pad
        let mut out = vec![0.0f32; s * 2];
        let mut scores = vec![0.0f32; s];
        attention(&q, &k, &v, &mask, &mut out, &mut scores, s, heads, dh);
        // mean of rows 0..3 of v = [(0+2+4)/3, (1+3+5)/3] = [2, 3]
        assert!((out[0] - 2.0).abs() < 1e-5);
        assert!((out[1] - 3.0).abs() < 1e-5);
    }

    fn small() -> NativeEncoder {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 512;
        p.dim = 96;
        p.hidden = 192;
        p.heads = 4;
        NativeEncoder::new(p)
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_calls() {
        let enc = small();
        let ids = enc.tokenizer().encode("how do i reset my password");
        let other = enc.tokenizer().encode("a totally different query");
        let mut scratch = EncodeScratch::default();
        let mut a = vec![0.0f32; enc.dim()];
        enc.encode_ids_into(&ids, &mut scratch, &mut a);
        // Dirty the arena with another sequence, then re-encode.
        let mut junk = vec![0.0f32; enc.dim()];
        enc.encode_ids_into(&other, &mut scratch, &mut junk);
        let mut b = vec![0.0f32; enc.dim()];
        enc.encode_ids_into(&ids, &mut scratch, &mut b);
        assert_eq!(a, b, "arena reuse must not leak state between encodes");
        assert_eq!(a, enc.encode_ids(&ids), "thread-local path agrees");
    }

    #[test]
    fn parallel_batch_matches_sequential_bitwise() {
        let enc = small();
        let texts: Vec<String> =
            (0..13).map(|i| format!("query number {i} about topic {}", i % 3)).collect();
        let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
        let seq = enc.encode_batch_with_workers(&refs, 1);
        for w in [2, 3, 4, 8] {
            let par = enc.encode_batch_with_workers(&refs, w);
            assert_eq!(seq, par, "worker count {w} diverged");
        }
    }

    #[test]
    fn memoized_encoder_hits_on_repeat_and_is_bit_identical() {
        let enc = small()
            .with_memo(MemoConfig { capacity: 64, shards: 2 })
            .unwrap();
        let cold = enc.encode_batch_tracked(&["repeat me", "only once"], false);
        assert!(cold.iter().all(|o| !o.memo_hit), "first sight is a miss");
        let warm = enc.encode_batch_tracked(&["repeat me"], false);
        assert!(warm[0].memo_hit, "second sight hits the memo");
        assert_eq!(warm[0].embedding, cold[0].embedding, "memo returns the exact vector");
        // Bypass skips the tier but still agrees bitwise.
        let bypass = enc.encode_batch_tracked(&["repeat me"], true);
        assert!(!bypass[0].memo_hit);
        assert_eq!(bypass[0].embedding, cold[0].embedding);
        let c = Encoder::memo_counters(&enc).unwrap();
        assert_eq!(c.hits, 1);
        assert!(c.misses >= 2);
        assert_eq!(Encoder::memo_flush(&enc), 2);
    }
}
