//! Pure-Rust forward pass of the encoder — the artifact-free twin of the
//! L2 JAX model. Formula-for-formula identical to `python/compile/model.py`
//! (parameter-free LayerNorm eps 1e-6, tanh-approximate GELU, masked mean
//! pool, zero-safe L2 normalize); `rust/tests/parity.rs` asserts the two
//! agree through PJRT to ~1e-4.

use crate::runtime::ModelParams;
use crate::tokenizer::{Tokenizer, PAD_ID};
use crate::util::dot;

use super::weights::EncoderWeights;
use super::Encoder;

/// CPU-native encoder: tokenizer + generated weights + forward pass.
pub struct NativeEncoder {
    weights: EncoderWeights,
    tokenizer: Tokenizer,
}

const LN_EPS: f32 = 1e-6;

impl NativeEncoder {
    pub fn new(params: ModelParams) -> Self {
        let tokenizer = Tokenizer::new(params.vocab_size, params.seq_len);
        Self { weights: EncoderWeights::generate(&params), tokenizer }
    }

    /// The default MiniLM-geometry simulation encoder (DESIGN.md §3).
    pub fn minilm_sim() -> Self {
        Self::new(ModelParams::default())
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn weights(&self) -> &EncoderWeights {
        &self.weights
    }

    /// Encode pre-tokenized ids (one sequence) to a unit vector.
    pub fn encode_ids(&self, ids: &[i64]) -> Vec<f32> {
        let p = &self.weights.params;
        assert_eq!(ids.len(), p.seq_len);
        let (s, d, h) = (p.seq_len, p.dim, p.hidden);
        let heads = p.heads;
        let dh = d / heads;

        // x = embed[tokens] + pos
        let mut x = vec![0.0f32; s * d];
        for (i, &t) in ids.iter().enumerate() {
            let row = self.weights.embed_row(t);
            let pos = &self.weights.pos[i * d..(i + 1) * d];
            for j in 0..d {
                x[i * d + j] = row[j] + pos[j];
            }
        }
        let mask: Vec<f32> =
            ids.iter().map(|&t| if t == PAD_ID { 0.0 } else { 1.0 }).collect();

        let mut hbuf = vec![0.0f32; s * d];
        let mut q = vec![0.0f32; s * d];
        let mut k = vec![0.0f32; s * d];
        let mut v = vec![0.0f32; s * d];
        let mut ctx = vec![0.0f32; s * d];
        let mut ffn_h = vec![0.0f32; s * h];

        for l in 0..p.layers {
            // --- attention block: x += (attn(LN(x))) @ wo
            layer_norm_rows(&x, &mut hbuf, s, d);
            let wq = EncoderWeights::layer(&self.weights.wq, l, d, d);
            let wk = EncoderWeights::layer(&self.weights.wk, l, d, d);
            let wv = EncoderWeights::layer(&self.weights.wv, l, d, d);
            let wo = EncoderWeights::layer(&self.weights.wo, l, d, d);
            matmul(&hbuf, wq, &mut q, s, d, d);
            matmul(&hbuf, wk, &mut k, s, d, d);
            matmul(&hbuf, wv, &mut v, s, d, d);
            attention(&q, &k, &v, &mask, &mut ctx, s, heads, dh);
            matmul_add(&ctx, wo, &mut x, s, d, d);

            // --- FFN block: x += gelu(LN(x) @ w1) @ w2
            layer_norm_rows(&x, &mut hbuf, s, d);
            let w1 = EncoderWeights::layer(&self.weights.w1, l, d, h);
            let w2 = EncoderWeights::layer(&self.weights.w2, l, h, d);
            matmul(&hbuf, w1, &mut ffn_h, s, d, h);
            for e in ffn_h.iter_mut() {
                *e = gelu(*e);
            }
            matmul_add(&ffn_h, w2, &mut x, s, h, d);
        }

        layer_norm_rows(&x.clone(), &mut x, s, d);

        // Masked mean pool + L2 normalize (zero-safe).
        let denom = mask.iter().sum::<f32>().max(1.0);
        let mut pooled = vec![0.0f32; d];
        for i in 0..s {
            if mask[i] > 0.0 {
                for j in 0..d {
                    pooled[j] += x[i * d + j];
                }
            }
        }
        for e in pooled.iter_mut() {
            *e /= denom;
        }
        let n = dot(&pooled, &pooled).sqrt().max(1e-12);
        for e in pooled.iter_mut() {
            *e /= n;
        }
        pooled
    }
}

impl Encoder for NativeEncoder {
    fn dim(&self) -> usize {
        self.weights.params.dim
    }

    fn encode_batch(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        texts
            .iter()
            .map(|t| self.encode_ids(&self.tokenizer.encode(t)))
            .collect()
    }

    fn params(&self) -> &ModelParams {
        &self.weights.params
    }
}

/// tanh-approximate GELU (matches `jax` model twin exactly in formula).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise parameter-free LayerNorm: out[i] = (x[i]-mu)/sqrt(var+eps).
fn layer_norm_rows(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..cols {
            out[r * cols + c] = (row[c] - mu) * inv;
        }
    }
}

/// out = a (rows×inner) @ b (inner×cols). b is row-major; we walk it
/// column-by-row via a transposed scratch — at these sizes (≤768) a
/// simple k-blocked loop with the vectorized `dot` on transposed tiles
/// costs more than it saves, so use the classic ikj order which keeps
/// `b` rows streaming and autovectorizes the inner j loop.
fn matmul(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, rows, inner, cols);
}

/// out += a @ b (residual add fused into the accumulation).
fn matmul_add(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    matmul_acc(a, b, out, rows, inner, cols);
}

#[inline]
fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, inner: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        let a_row = &a[i * inner..(i + 1) * inner];
        let o_row = &mut out[i * cols..(i + 1) * cols];
        for (kk, &aik) in a_row.iter().enumerate() {
            let b_row = &b[kk * cols..(kk + 1) * cols];
            for j in 0..cols {
                o_row[j] += aik * b_row[j];
            }
        }
    }
}

/// Multi-head masked attention over row-major (S, D) q/k/v.
fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    out: &mut [f32],
    s: usize,
    heads: usize,
    dh: usize,
) {
    let d = heads * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0.0f32; s];
    for hd in 0..heads {
        let off = hd * dh;
        for i in 0..s {
            let qi = &q[i * d + off..i * d + off + dh];
            let mut max = f32::MIN;
            for j in 0..s {
                let kj = &k[j * d + off..j * d + off + dh];
                let mut sc = dot(qi, kj) * scale;
                sc += (1.0 - mask[j]) * -1e9;
                scores[j] = sc;
                if sc > max {
                    max = sc;
                }
            }
            let mut sum = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                sum += *sc;
            }
            let inv = 1.0 / sum;
            let o = &mut out[i * d + off..i * d + off + dh];
            o.fill(0.0);
            for j in 0..s {
                let w = scores[j] * inv;
                let vj = &v[j * d + off..j * d + off + dh];
                for c in 0..dh {
                    o[c] += w * vj[c];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3x2
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 3, 2);
        assert_eq!(out, [58.0, 64.0, 139.0, 154.0]);
        // matmul_add accumulates.
        matmul_add(&a, &b, &mut out, 2, 3, 2);
        assert_eq!(out, [116.0, 128.0, 278.0, 308.0]);
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let mut out = [0.0f32; 8];
        layer_norm_rows(&x, &mut out, 2, 4);
        for r in 0..2 {
            let row = &out[r * 4..(r + 1) * 4];
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
    }

    #[test]
    fn attention_uniform_when_keys_equal() {
        // All keys identical -> probs uniform over unmasked -> out = mean(v).
        let s = 4;
        let (heads, dh) = (1, 2);
        let q = vec![1.0f32; s * 2];
        let k = vec![1.0f32; s * 2];
        let v: Vec<f32> = (0..s * 2).map(|i| i as f32).collect();
        let mask = vec![1.0f32, 1.0, 1.0, 0.0]; // last is pad
        let mut out = vec![0.0f32; s * 2];
        attention(&q, &k, &v, &mask, &mut out, s, heads, dh);
        // mean of rows 0..3 of v = [(0+2+4)/3, (1+3+5)/3] = [2, 3]
        assert!((out[0] - 2.0).abs() < 1e-5);
        assert!((out[1] - 3.0).abs() < 1e-5);
    }
}
