//! Generated encoder weights — Rust twin of `python/compile/weights.py`.
//!
//! The `(label, shape, std)` table below must match `weight_table()` in
//! Python exactly; every tensor is filled from
//! `SplitMix64::derive(seed, label)` in row-major order.

use crate::runtime::ModelParams;
use crate::util::SplitMix64;

/// All encoder parameter tensors, flattened row-major.
#[derive(Debug, Clone)]
pub struct EncoderWeights {
    pub params: ModelParams,
    /// (vocab, dim)
    pub embed: Vec<f32>,
    /// (seq_len, dim)
    pub pos: Vec<f32>,
    /// (layers, dim, dim) each
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    /// (layers, dim, hidden)
    pub w1: Vec<f32>,
    /// (layers, hidden, dim)
    pub w2: Vec<f32>,
}

impl EncoderWeights {
    /// Generate every tensor from the shared seed (≈6M normals, ~100 ms).
    pub fn generate(params: &ModelParams) -> Self {
        let p = params;
        let d = p.dim;
        let inv_sqrt_d = 1.0 / (d as f64).sqrt();
        let inv_sqrt_h = 1.0 / (p.hidden as f64).sqrt();
        let gen = |label: &str, n: usize, std: f64| -> Vec<f32> {
            SplitMix64::derive(p.seed, label).normal_vec(n, std)
        };
        Self {
            params: p.clone(),
            embed: gen("embed", p.vocab_size * d, 1.0),
            pos: gen("pos", p.seq_len * d, 0.1),
            wq: gen("wq", p.layers * d * d, inv_sqrt_d),
            wk: gen("wk", p.layers * d * d, inv_sqrt_d),
            wv: gen("wv", p.layers * d * d, inv_sqrt_d),
            wo: gen("wo", p.layers * d * d, 0.1 * inv_sqrt_d),
            w1: gen("w1", p.layers * d * p.hidden, inv_sqrt_d),
            w2: gen("w2", p.layers * p.hidden * d, 0.1 * inv_sqrt_h),
        }
    }

    /// Tensors in the positional order of the AOT executable signature
    /// (after the token input): `(data, shape)` pairs.
    pub fn flat_inputs(&self) -> Vec<(&[f32], Vec<usize>)> {
        let p = &self.params;
        vec![
            (self.embed.as_slice(), vec![p.vocab_size, p.dim]),
            (self.pos.as_slice(), vec![p.seq_len, p.dim]),
            (self.wq.as_slice(), vec![p.layers, p.dim, p.dim]),
            (self.wk.as_slice(), vec![p.layers, p.dim, p.dim]),
            (self.wv.as_slice(), vec![p.layers, p.dim, p.dim]),
            (self.wo.as_slice(), vec![p.layers, p.dim, p.dim]),
            (self.w1.as_slice(), vec![p.layers, p.dim, p.hidden]),
            (self.w2.as_slice(), vec![p.layers, p.hidden, p.dim]),
        ]
    }

    /// Layer-`l` slice of a stacked (layers, rows, cols) tensor.
    pub fn layer<'a>(stacked: &'a [f32], l: usize, rows: usize, cols: usize) -> &'a [f32] {
        &stacked[l * rows * cols..(l + 1) * rows * cols]
    }

    /// Embedding row for a token id.
    pub fn embed_row(&self, token: i64) -> &[f32] {
        let d = self.params.dim;
        let t = token as usize;
        &self.embed[t * d..(t + 1) * d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let mut p = ModelParams::default();
        // Shrink for test speed; geometry constraints still hold.
        p.vocab_size = 64;
        p.layers = 2;
        let w1 = EncoderWeights::generate(&p);
        let w2 = EncoderWeights::generate(&p);
        assert_eq!(w1.embed.len(), 64 * p.dim);
        assert_eq!(w1.wq.len(), 2 * p.dim * p.dim);
        assert_eq!(w1.embed, w2.embed);
        assert_eq!(w1.w2, w2.w2);
    }

    #[test]
    fn streams_are_independent() {
        let mut p = ModelParams::default();
        p.vocab_size = 8;
        p.layers = 1;
        let w = EncoderWeights::generate(&p);
        assert_ne!(w.wq[..16], w.wk[..16]);
        assert_ne!(w.wq[..16], w.wv[..16]);
    }

    #[test]
    fn scale_ordering() {
        // Output projections are down-scaled 10x vs inputs.
        let mut p = ModelParams::default();
        p.vocab_size = 8;
        let w = EncoderWeights::generate(&p);
        let rms = |v: &[f32]| {
            (v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / v.len() as f64).sqrt()
        };
        assert!(rms(&w.wo) < rms(&w.wq) / 5.0);
        assert!(rms(&w.embed) > 0.9 && rms(&w.embed) < 1.1);
    }
}
