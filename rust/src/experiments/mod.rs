//! Experiment harness — regenerates every table and figure of the paper
//! (DESIGN.md §5 experiment index):
//!
//! * [`run_paper_eval`] — the §3 protocol: populate 8,000 QA pairs, run
//!   2,000 test queries, tally per-category hits / positive hits / API
//!   calls / latencies → **Table 1, Figure 2, Figure 3, Figure 4**;
//! * [`threshold_sweep`] — §5.3: θ from 0.60 to 0.90 in 0.05 steps,
//!   hit rate vs positive rate trade-off;
//! * [`scaling_study`] — §2.4: HNSW vs exhaustive search latency and
//!   recall as the index grows.
//!
//! The expensive part (embedding 10,000 texts) happens once in
//! [`EvalContext::build`] and is shared by all experiments.

mod context;
mod eval;
mod render;
mod scaling;
mod sweep;

pub use context::EvalContext;
pub use eval::{run_paper_eval, CategoryRow, PaperEval, PaperEvalConfig};
pub use render::{render_fig2, render_fig3, render_fig4, render_scaling, render_sweep, render_table1};
pub use scaling::{scaling_study, ScalingConfig, ScalingRow};
pub use sweep::{paper_grid as sweep_grid, threshold_sweep, SweepRow};
