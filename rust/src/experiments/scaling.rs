//! Index scaling study — the paper's §2.4 complexity claim: HNSW search
//! is ~O(log n) vs the exhaustive scan's O(n). Measures per-query search
//! latency and recall@k for both index kinds as n grows.

use std::time::Instant;

use crate::index::{FlatIndex, HnswConfig, HnswIndex, VectorIndex};
use crate::json::{obj, Value};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ScalingConfig {
    pub dim: usize,
    pub sizes: Vec<usize>,
    pub queries: usize,
    pub k: usize,
    pub hnsw: HnswConfig,
    pub seed: u64,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        Self {
            dim: 384,
            sizes: vec![1_000, 2_000, 4_000, 8_000, 16_000, 32_000],
            queries: 200,
            k: 10,
            hnsw: HnswConfig::default(),
            seed: 0x5CA1E,
        }
    }
}

/// One (n, index-kind) measurement.
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub n: usize,
    pub flat_us_per_query: f64,
    pub hnsw_us_per_query: f64,
    /// HNSW recall@k against the flat oracle.
    pub hnsw_recall: f64,
    /// HNSW build time for this n, ms.
    pub hnsw_build_ms: f64,
}

impl ScalingRow {
    pub fn speedup(&self) -> f64 {
        self.flat_us_per_query / self.hnsw_us_per_query.max(1e-9)
    }
    pub fn to_json(&self) -> Value {
        obj([
            ("n", self.n.into()),
            ("flat_us_per_query", self.flat_us_per_query.into()),
            ("hnsw_us_per_query", self.hnsw_us_per_query.into()),
            ("hnsw_recall", self.hnsw_recall.into()),
            ("hnsw_build_ms", self.hnsw_build_ms.into()),
            ("speedup", self.speedup().into()),
        ])
    }
}

/// Clustered synthetic embeddings (unit vectors around random centers) —
/// closer to cached-question geometry than i.i.d. noise.
fn clustered_vectors(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    let n_centers = (n / 12).clamp(64, 8192);
    let centers: Vec<Vec<f32>> = (0..n_centers)
        .map(|_| (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centers[i % n_centers];
            c.iter()
                .map(|x| x + rng.range_f64(-0.25, 0.25) as f32)
                .collect()
        })
        .collect()
}

pub fn scaling_study(cfg: &ScalingConfig) -> Vec<ScalingRow> {
    let mut rng = Rng::new(cfg.seed);
    let max_n = *cfg.sizes.iter().max().unwrap_or(&0);
    let all = clustered_vectors(&mut rng, max_n, cfg.dim);
    // Queries are perturbed copies of stored vectors — the cache-lookup
    // geometry (a query lands near its paraphrase cluster), and the
    // regime where the paper's recall expectations apply. Use only rows
    // present at the *smallest* size so every study point sees them.
    let min_n = *cfg.sizes.iter().min().unwrap_or(&1);
    let queries: Vec<Vec<f32>> = (0..cfg.queries)
        .map(|_| {
            let row = &all[rng.below(min_n)];
            row.iter().map(|x| x + rng.range_f64(-0.08, 0.08) as f32).collect()
        })
        .collect();

    cfg.sizes
        .iter()
        .map(|&n| {
            let mut flat = FlatIndex::new(cfg.dim);
            let t0 = Instant::now();
            let mut hnsw = HnswIndex::new(cfg.dim, cfg.hnsw.clone());
            for (i, v) in all[..n].iter().enumerate() {
                hnsw.insert(i as u64, v);
            }
            let hnsw_build_ms = t0.elapsed().as_secs_f64() * 1e3;
            for (i, v) in all[..n].iter().enumerate() {
                flat.insert(i as u64, v);
            }

            // Measure.
            let t0 = Instant::now();
            let truth: Vec<Vec<u64>> = queries
                .iter()
                .map(|q| flat.search(q, cfg.k).iter().map(|r| r.id).collect())
                .collect();
            let flat_us = t0.elapsed().as_secs_f64() * 1e6 / cfg.queries as f64;

            let t0 = Instant::now();
            let got: Vec<Vec<u64>> = queries
                .iter()
                .map(|q| hnsw.search(q, cfg.k).iter().map(|r| r.id).collect())
                .collect();
            let hnsw_us = t0.elapsed().as_secs_f64() * 1e6 / cfg.queries as f64;

            let mut found = 0usize;
            let mut total = 0usize;
            for (t, g) in truth.iter().zip(&got) {
                total += t.len();
                found += g.iter().filter(|id| t.contains(id)).count();
            }

            ScalingRow {
                n,
                flat_us_per_query: flat_us,
                hnsw_us_per_query: hnsw_us,
                hnsw_recall: found as f64 / total.max(1) as f64,
                hnsw_build_ms,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sublinear_hnsw_vs_linear_flat() {
        let cfg = ScalingConfig {
            dim: 64,
            sizes: vec![1_000, 8_000],
            queries: 50,
            ..Default::default()
        };
        let rows = scaling_study(&cfg);
        assert_eq!(rows.len(), 2);
        // Flat grows ~8x; HNSW must grow much slower.
        let flat_growth = rows[1].flat_us_per_query / rows[0].flat_us_per_query;
        let hnsw_growth = rows[1].hnsw_us_per_query / rows[0].hnsw_us_per_query;
        assert!(flat_growth > 4.0, "flat growth {flat_growth}");
        assert!(hnsw_growth < flat_growth * 0.9, "hnsw growth {hnsw_growth} vs flat {flat_growth}");
        // And stays accurate. (HNSW has fixed traversal overhead, so the
        // speedup claim only holds beyond the small-n crossover — assert
        // it at the largest size, which is the regime the paper targets.)
        for r in &rows {
            assert!(r.hnsw_recall > 0.85, "recall {} at n={}", r.hnsw_recall, r.n);
        }
        assert!(
            rows.last().unwrap().speedup() > 1.0,
            "hnsw slower than flat at n={}",
            rows.last().unwrap().n
        );
    }
}
