//! Shared evaluation context: the dataset plus every embedding,
//! precomputed in batches (the costly step), and a measured estimate of
//! single-query embedding latency for the Figure 3 latency model.

use std::time::Instant;

use crate::embedding::Encoder;
use crate::util::Summary;
use crate::workload::{Dataset, DatasetConfig, WorkloadGenerator};

pub struct EvalContext {
    pub dataset: Dataset,
    /// One embedding per `dataset.base` entry, in order.
    pub base_embeddings: Vec<Vec<f32>>,
    /// One embedding per `dataset.tests` entry, in order.
    pub test_embeddings: Vec<Vec<f32>>,
    /// Measured per-query (batch=1) embed latency, ms.
    pub embed_latency: Summary,
    pub dim: usize,
}

impl EvalContext {
    /// Generate the dataset and embed everything. `encoder` is the
    /// backend under test (PJRT in the shipped experiments, native as
    /// the artifact-free fallback).
    pub fn build(encoder: &dyn Encoder, cfg: &DatasetConfig, seed: u64) -> Self {
        let dataset = WorkloadGenerator::new(seed).generate(cfg);
        let base_embeddings = embed_all(
            encoder,
            dataset.base.iter().map(|p| p.question.as_str()),
            dataset.base.len(),
        );
        let test_embeddings = embed_all(
            encoder,
            dataset.tests.iter().map(|q| q.text.as_str()),
            dataset.tests.len(),
        );

        // Measure the single-query path on a sample (this is what a
        // serving request actually pays; the batched path above is the
        // population pipeline).
        let sample: Vec<&str> = dataset
            .tests
            .iter()
            .take(32)
            .map(|q| q.text.as_str())
            .collect();
        let mut lat = Vec::with_capacity(sample.len());
        for text in sample {
            let t0 = Instant::now();
            let _ = encoder.encode_text(text);
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }

        Self {
            dim: encoder.dim(),
            dataset,
            base_embeddings,
            test_embeddings,
            embed_latency: Summary::of(&lat),
        }
    }
}

fn embed_all<'a>(
    encoder: &dyn Encoder,
    texts: impl Iterator<Item = &'a str>,
    n: usize,
) -> Vec<Vec<f32>> {
    let texts: Vec<&str> = texts.collect();
    let mut out = Vec::with_capacity(n);
    for chunk in texts.chunks(64) {
        out.extend(encoder.encode_batch(chunk));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;

    #[test]
    fn context_shapes_line_up() {
        let mut p = ModelParams::default();
        p.layers = 1;
        p.vocab_size = 512;
        p.dim = 64;
        p.hidden = 128;
        p.heads = 4;
        let enc = NativeEncoder::new(p);
        let ctx = EvalContext::build(&enc, &DatasetConfig::tiny(), 5);
        assert_eq!(ctx.base_embeddings.len(), ctx.dataset.base.len());
        assert_eq!(ctx.test_embeddings.len(), ctx.dataset.tests.len());
        assert_eq!(ctx.dim, 64);
        assert!(ctx.embed_latency.mean > 0.0);
        for e in ctx.base_embeddings.iter().chain(&ctx.test_embeddings) {
            assert_eq!(e.len(), 64);
        }
    }
}
