//! The paper's §3 evaluation protocol over a prebuilt [`EvalContext`].

use std::time::Instant;

use crate::cache::{CacheConfig, CachedEntry, SemanticCache};
use crate::json::{obj, Value};
use crate::llm::{approx_tokens, Judge, JudgeConfig, SimLlm, SimLlmConfig};
use crate::metrics::CostModel;
use crate::workload::{Category, ALL_CATEGORIES};

use super::context::EvalContext;

#[derive(Debug, Clone, Default)]
pub struct PaperEvalConfig {
    pub cache: CacheConfig,
    pub llm: SimLlmConfig,
    pub judge: JudgeConfig,
    pub cost: CostModel,
}

/// One Table-1 / Figure-2/3/4 row.
#[derive(Debug, Clone)]
pub struct CategoryRow {
    pub category: Category,
    pub queries: usize,
    pub cache_hits: usize,
    pub positive_hits: usize,
    pub api_calls: usize,
    /// Mean end-to-end ms with the cache in front.
    pub avg_ms_with_cache: f64,
    /// Mean end-to-end ms via the traditional always-LLM path.
    pub avg_ms_without_cache: f64,
    pub cost_with_usd: f64,
    pub cost_without_usd: f64,
}

impl CategoryRow {
    pub fn hit_rate(&self) -> f64 {
        self.cache_hits as f64 / self.queries.max(1) as f64
    }
    pub fn positive_rate(&self) -> f64 {
        self.positive_hits as f64 / self.cache_hits.max(1) as f64
    }
    pub fn api_rate(&self) -> f64 {
        self.api_calls as f64 / self.queries.max(1) as f64
    }
}

/// Full evaluation output.
#[derive(Debug, Clone)]
pub struct PaperEval {
    pub rows: Vec<CategoryRow>,
    /// Wall-clock of the lookup phase (all 2,000 queries), seconds.
    pub lookup_wall_secs: f64,
    /// Mean measured per-query embed latency used in the latency model, ms.
    pub embed_ms: f64,
    /// Mean measured ANN lookup latency, ms.
    pub index_ms: f64,
}

pub fn run_paper_eval(ctx: &EvalContext, cfg: &PaperEvalConfig) -> PaperEval {
    let cache = SemanticCache::new(cfg.cache.clone());
    let llm = SimLlm::new(cfg.llm.clone());
    let judge = Judge::new(cfg.judge.clone());

    // §3.1: populate the cache with all 8,000 pairs. Entries carry the
    // answer-group id — the judge's ground truth (see workload docs).
    for (p, e) in ctx.dataset.base.iter().zip(&ctx.base_embeddings) {
        cache
            .try_insert_entry(
                e,
                CachedEntry {
                    question: p.question.clone(),
                    response: p.answer.clone(),
                    cluster: p.answer_group,
                    latency_ms: 0.0,
                },
            )
            .expect("populate insert");
    }

    struct Tally {
        queries: usize,
        hits: usize,
        positives: usize,
        api_calls: usize,
        with_ms: f64,
        without_ms: f64,
        llm_in_tokens: u64,
        llm_out_tokens: u64,
        embed_tokens: u64,
        baseline_in_tokens: u64,
        baseline_out_tokens: u64,
    }
    let mut tallies: std::collections::HashMap<Category, Tally> = ALL_CATEGORIES
        .into_iter()
        .map(|c| {
            (
                c,
                Tally {
                    queries: 0,
                    hits: 0,
                    positives: 0,
                    api_calls: 0,
                    with_ms: 0.0,
                    without_ms: 0.0,
                    llm_in_tokens: 0,
                    llm_out_tokens: 0,
                    embed_tokens: 0,
                    baseline_in_tokens: 0,
                    baseline_out_tokens: 0,
                },
            )
        })
        .collect();

    let embed_ms = ctx.embed_latency.mean;
    let mut index_ms_total = 0.0;
    let ground_truth: std::collections::HashMap<u64, &str> = ctx
        .dataset
        .base
        .iter()
        .map(|p| (p.answer_group, p.answer.as_str()))
        .collect();
    let t_wall = Instant::now();

    // §3.2: run the 2,000 test queries (embedding precomputed; the
    // per-query embed cost enters the latency model as the measured mean).
    for (q, e) in ctx.dataset.tests.iter().zip(&ctx.test_embeddings) {
        let t = tallies.get_mut(&q.category).unwrap();
        t.queries += 1;
        t.embed_tokens += approx_tokens(&q.text);

        let t0 = Instant::now();
        let hit = cache.lookup(e);
        let index_ms = t0.elapsed().as_secs_f64() * 1e3;
        index_ms_total += index_ms;

        match hit {
            Some(hit) => {
                t.hits += 1;
                if judge.validate(q.answer_group, hit.entry.cluster) {
                    t.positives += 1;
                }
                t.with_ms += embed_ms + index_ms;
            }
            None => {
                // Miss: LLM call + insert (paper §2.5 step 2).
                let resp = llm
                    .call(&q.text, ground_truth.get(&q.answer_group).copied())
                    .expect("experiments run without fault injection");
                t.api_calls += 1;
                t.llm_in_tokens += resp.input_tokens;
                t.llm_out_tokens += resp.output_tokens;
                t.with_ms += embed_ms + index_ms + resp.latency_ms;
                cache
                    .try_insert_entry(
                        e,
                        CachedEntry {
                            question: q.text.clone(),
                            response: resp.text,
                            cluster: q.answer_group,
                            latency_ms: resp.latency_ms,
                        },
                    )
                    .expect("miss insert");
            }
        }

        // Traditional baseline: every query goes to the LLM.
        let base = llm.call(&q.text, None).expect("experiments run without fault injection");
        t.without_ms += base.latency_ms;
        t.baseline_in_tokens += base.input_tokens;
        t.baseline_out_tokens += base.output_tokens;
    }
    let lookup_wall_secs = t_wall.elapsed().as_secs_f64();

    let rows = ALL_CATEGORIES
        .into_iter()
        .map(|c| {
            let t = &tallies[&c];
            let cost_with = (t.llm_in_tokens as f64 * cfg.cost.usd_per_1m_input_tokens
                + t.llm_out_tokens as f64 * cfg.cost.usd_per_1m_output_tokens
                + t.embed_tokens as f64 * cfg.cost.usd_per_1m_embedding_tokens)
                / 1e6;
            let cost_without = (t.baseline_in_tokens as f64 * cfg.cost.usd_per_1m_input_tokens
                + t.baseline_out_tokens as f64 * cfg.cost.usd_per_1m_output_tokens)
                / 1e6;
            CategoryRow {
                category: c,
                queries: t.queries,
                cache_hits: t.hits,
                positive_hits: t.positives,
                api_calls: t.api_calls,
                avg_ms_with_cache: t.with_ms / t.queries.max(1) as f64,
                avg_ms_without_cache: t.without_ms / t.queries.max(1) as f64,
                cost_with_usd: cost_with,
                cost_without_usd: cost_without,
            }
        })
        .collect();

    PaperEval {
        rows,
        lookup_wall_secs,
        embed_ms,
        index_ms: index_ms_total / ctx.dataset.tests.len().max(1) as f64,
    }
}

impl PaperEval {
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                obj([
                    ("category", r.category.key().into()),
                    ("label", r.category.label().into()),
                    ("queries", r.queries.into()),
                    ("cache_hits", r.cache_hits.into()),
                    ("positive_hits", r.positive_hits.into()),
                    ("api_calls", r.api_calls.into()),
                    ("hit_rate", r.hit_rate().into()),
                    ("positive_rate", r.positive_rate().into()),
                    ("api_rate", r.api_rate().into()),
                    ("avg_ms_with_cache", r.avg_ms_with_cache.into()),
                    ("avg_ms_without_cache", r.avg_ms_without_cache.into()),
                    ("cost_with_usd", r.cost_with_usd.into()),
                    ("cost_without_usd", r.cost_without_usd.into()),
                ])
            })
            .collect();
        obj([
            ("rows", Value::Array(rows)),
            ("lookup_wall_secs", self.lookup_wall_secs.into()),
            ("embed_ms", self.embed_ms.into()),
            ("index_ms", self.index_ms.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;
    use crate::workload::DatasetConfig;

    fn small_ctx() -> EvalContext {
        let mut p = ModelParams::default();
        p.layers = 2;
        p.vocab_size = 2048;
        p.dim = 128;
        p.hidden = 256;
        p.heads = 4;
        let enc = NativeEncoder::new(p);
        EvalContext::build(&enc, &DatasetConfig::small(), 11)
    }

    #[test]
    fn eval_reproduces_paper_shape_at_small_scale() {
        let ctx = small_ctx();
        let eval = run_paper_eval(&ctx, &PaperEvalConfig::default());
        assert_eq!(eval.rows.len(), 4);
        for r in &eval.rows {
            assert_eq!(r.queries, 80);
            assert_eq!(r.cache_hits + r.api_calls, r.queries, "{:?}", r.category);
            // Shape claims (wide bands at this tiny scale): real hit
            // rates, high accuracy, order-of-magnitude latency win.
            assert!(r.hit_rate() > 0.35, "{:?} hit rate {}", r.category, r.hit_rate());
            assert!(r.hit_rate() < 0.95, "{:?} hit rate {}", r.category, r.hit_rate());
            assert!(
                r.positive_rate() > 0.7,
                "{:?} positive rate {}",
                r.category,
                r.positive_rate()
            );
            // The weak test-geometry encoder hits less often than the
            // shipped 384-d model, so the latency win is smaller here;
            // the paper-shape ratio is asserted by the bench harness.
            assert!(
                r.avg_ms_without_cache > 2.0 * r.avg_ms_with_cache,
                "{:?}: cache {}ms vs llm {}ms",
                r.category,
                r.avg_ms_with_cache,
                r.avg_ms_without_cache
            );
            assert!(r.cost_with_usd < r.cost_without_usd);
        }
        let j = eval.to_json();
        assert_eq!(j.get("rows").as_array().unwrap().len(), 4);
    }
}
