//! Render experiment results as the paper's tables/figures (markdown,
//! with ASCII bars standing in for the bar charts).

use super::eval::PaperEval;
use super::scaling::ScalingRow;
use super::sweep::SweepRow;

fn bar(frac: f64, width: usize) -> String {
    let n = (frac.clamp(0.0, 1.0) * width as f64).round() as usize;
    let mut s = "█".repeat(n);
    s.push_str(&"░".repeat(width - n.min(width)));
    s
}

/// Table 1: cache hits and positive hits per 500 queries per category.
pub fn render_table1(eval: &PaperEval) -> String {
    let mut out = String::from(
        "## Table 1 — Cache hits per category and positive hits\n\n\
         | Category | Queries | Cache hits | Positive hits | Hit rate | Positive rate |\n\
         |---|---:|---:|---:|---:|---:|\n",
    );
    for r in &eval.rows {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.1}% | {:.1}% |\n",
            r.category.label(),
            r.queries,
            r.cache_hits,
            r.positive_hits,
            100.0 * r.hit_rate(),
            100.0 * r.positive_rate(),
        ));
    }
    out
}

/// Figure 2: API-call frequency, traditional vs semantic cache.
pub fn render_fig2(eval: &PaperEval) -> String {
    let mut out = String::from(
        "## Figure 2 — API call frequency: traditional vs GPT Semantic Cache\n\n",
    );
    for r in &eval.rows {
        out.push_str(&format!(
            "{:<42} traditional {} 100.0%\n{:<42} cached      {} {:>5.1}%\n\n",
            r.category.label(),
            bar(1.0, 30),
            "",
            bar(r.api_rate(), 30),
            100.0 * r.api_rate(),
        ));
    }
    out
}

/// Figure 3: average response time with vs without cache.
pub fn render_fig3(eval: &PaperEval) -> String {
    let max_ms = eval
        .rows
        .iter()
        .map(|r| r.avg_ms_without_cache)
        .fold(1.0f64, f64::max);
    let mut out = String::from(
        "## Figure 3 — Average query response time (ms): with vs without cache\n\n",
    );
    for r in &eval.rows {
        out.push_str(&format!(
            "{:<42} no cache   {} {:>9.1} ms\n{:<42} with cache {} {:>9.1} ms  ({:.1}x faster)\n\n",
            r.category.label(),
            bar(r.avg_ms_without_cache / max_ms, 30),
            r.avg_ms_without_cache,
            "",
            bar(r.avg_ms_with_cache / max_ms, 30),
            r.avg_ms_with_cache,
            r.avg_ms_without_cache / r.avg_ms_with_cache.max(1e-9),
        ));
    }
    out.push_str(&format!(
        "(measured components: embed {:.3} ms/query, ANN lookup {:.3} ms/query)\n",
        eval.embed_ms, eval.index_ms
    ));
    out
}

/// Figure 4: hit rate + positive-match accuracy per category.
pub fn render_fig4(eval: &PaperEval) -> String {
    let mut out = String::from(
        "## Figure 4 — Cache hit rates and positive match accuracy\n\n\
         | Category | Hit rate | Positive accuracy |\n|---|---|---|\n",
    );
    for r in &eval.rows {
        out.push_str(&format!(
            "| {} | {} {:.1}% | {} {:.1}% |\n",
            r.category.label(),
            bar(r.hit_rate(), 20),
            100.0 * r.hit_rate(),
            bar(r.positive_rate(), 20),
            100.0 * r.positive_rate(),
        ));
    }
    out
}

/// §5.3 sweep table.
pub fn render_sweep(rows: &[SweepRow]) -> String {
    let mut out = String::from(
        "## §5.3 — Similarity-threshold sweep\n\n\
         | θ | Hit rate | Positive rate | Hits | Positives |\n|---:|---:|---:|---:|---:|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {:.2} | {:.1}% | {:.1}% | {} | {} |\n",
            r.threshold,
            100.0 * r.hit_rate(),
            100.0 * r.positive_rate(),
            r.hits,
            r.positives,
        ));
    }
    out
}

/// §2.4 scaling table.
pub fn render_scaling(rows: &[ScalingRow]) -> String {
    let mut out = String::from(
        "## §2.4 — HNSW (O(log n)) vs exhaustive search (O(n))\n\n\
         | n | flat µs/query | hnsw µs/query | speedup | recall@k | hnsw build ms |\n\
         |---:|---:|---:|---:|---:|---:|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.1} | {:.1} | {:.1}x | {:.3} | {:.0} |\n",
            r.n,
            r.flat_us_per_query,
            r.hnsw_us_per_query,
            r.speedup(),
            r.hnsw_recall,
            r.hnsw_build_ms,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::CategoryRow;
    use crate::workload::Category;

    fn fake_eval() -> PaperEval {
        PaperEval {
            rows: vec![CategoryRow {
                category: Category::PythonBasics,
                queries: 500,
                cache_hits: 335,
                positive_hits: 310,
                api_calls: 165,
                avg_ms_with_cache: 12.0,
                avg_ms_without_cache: 1500.0,
                cost_with_usd: 0.01,
                cost_without_usd: 0.05,
            }],
            lookup_wall_secs: 1.0,
            embed_ms: 5.0,
            index_ms: 0.1,
        }
    }

    #[test]
    fn renders_contain_key_numbers() {
        let e = fake_eval();
        let t1 = render_table1(&e);
        assert!(t1.contains("| 335 |"));
        assert!(t1.contains("67.0%"));
        assert!(t1.contains("92.5%"));
        let f2 = render_fig2(&e);
        assert!(f2.contains("33.0%"));
        let f3 = render_fig3(&e);
        assert!(f3.contains("125.0x faster"));
        let f4 = render_fig4(&e);
        assert!(f4.contains("67.0%"));
    }

    #[test]
    fn bar_widths() {
        assert_eq!(bar(0.0, 10).chars().filter(|&c| c == '█').count(), 0);
        assert_eq!(bar(1.0, 10).chars().filter(|&c| c == '█').count(), 10);
        assert_eq!(bar(0.5, 10).chars().filter(|&c| c == '█').count(), 5);
        assert_eq!(bar(2.0, 10).chars().filter(|&c| c == '█').count(), 10);
    }
}
