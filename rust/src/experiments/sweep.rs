//! Threshold sweep (paper §5.3): θ from 0.60 to 0.90 in 0.05 steps.
//!
//! The cache is populated once with the 8,000 base pairs; each θ then
//! replays the 2,000 test lookups *read-only* (misses do not insert, so
//! every θ sees the identical cache state — the controlled version of
//! the paper's experiment). Reports hit rate and positive rate per θ.

use crate::cache::{CacheConfig, CachedEntry, SemanticCache};
use crate::json::{obj, Value};
use crate::llm::{Judge, JudgeConfig};
use crate::workload::ALL_CATEGORIES;

use super::context::EvalContext;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub threshold: f32,
    pub queries: usize,
    pub hits: usize,
    pub positives: usize,
    /// Per-category hit rates (paper discusses the shopping-qa outlier).
    pub per_category_hit_rate: Vec<(String, f64)>,
}

impl SweepRow {
    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / self.queries.max(1) as f64
    }
    pub fn positive_rate(&self) -> f64 {
        self.positives as f64 / self.hits.max(1) as f64
    }
    pub fn to_json(&self) -> Value {
        obj([
            ("threshold", (self.threshold as f64).into()),
            ("queries", self.queries.into()),
            ("hits", self.hits.into()),
            ("positives", self.positives.into()),
            ("hit_rate", self.hit_rate().into()),
            ("positive_rate", self.positive_rate().into()),
        ])
    }
}

/// The paper's sweep grid: 0.60, 0.65, ..., 0.90.
pub fn paper_grid() -> Vec<f32> {
    (0..=6).map(|i| 0.60 + 0.05 * i as f32).collect()
}

pub fn threshold_sweep(
    ctx: &EvalContext,
    cache_cfg: &CacheConfig,
    judge_cfg: &JudgeConfig,
    thresholds: &[f32],
) -> Vec<SweepRow> {
    let cache = SemanticCache::new(cache_cfg.clone());
    let judge = Judge::new(judge_cfg.clone());
    for (p, e) in ctx.dataset.base.iter().zip(&ctx.base_embeddings) {
        cache
            .try_insert_entry(
                e,
                CachedEntry {
                    question: p.question.clone(),
                    response: p.answer.clone(),
                    cluster: p.answer_group,
                    latency_ms: 0.0,
                },
            )
            .expect("populate insert");
    }

    thresholds
        .iter()
        .map(|&threshold| {
            let mut hits = 0;
            let mut positives = 0;
            let mut per_cat: std::collections::HashMap<&str, (usize, usize)> =
                ALL_CATEGORIES.iter().map(|c| (c.key(), (0usize, 0usize))).collect();
            for (q, e) in ctx.dataset.tests.iter().zip(&ctx.test_embeddings) {
                let entry = per_cat.get_mut(q.category.key()).unwrap();
                entry.1 += 1;
                if let Some(hit) = cache.lookup_with_threshold(e, threshold) {
                    hits += 1;
                    entry.0 += 1;
                    if judge.validate(q.answer_group, hit.entry.cluster) {
                        positives += 1;
                    }
                }
            }
            SweepRow {
                threshold,
                queries: ctx.dataset.tests.len(),
                hits,
                positives,
                per_category_hit_rate: ALL_CATEGORIES
                    .iter()
                    .map(|c| {
                        let (h, n) = per_cat[c.key()];
                        (c.key().to_string(), h as f64 / n.max(1) as f64)
                    })
                    .collect(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::NativeEncoder;
    use crate::runtime::ModelParams;
    use crate::workload::DatasetConfig;

    #[test]
    fn sweep_tradeoff_shape() {
        let mut p = ModelParams::default();
        p.layers = 2;
        p.vocab_size = 2048;
        p.dim = 128;
        p.hidden = 256;
        p.heads = 4;
        let enc = NativeEncoder::new(p);
        let ctx = EvalContext::build(&enc, &DatasetConfig::small(), 13);
        let rows = threshold_sweep(
            &ctx,
            &CacheConfig::default(),
            &JudgeConfig::default(),
            &paper_grid(),
        );
        assert_eq!(rows.len(), 7);
        // Hit rate must be monotonically non-increasing in θ.
        for w in rows.windows(2) {
            assert!(
                w[0].hits >= w[1].hits,
                "hit rate must fall as θ rises: {} -> {}",
                w[0].threshold,
                w[1].threshold
            );
        }
        // The paper's trade-off: loosest θ has more hits but lower
        // accuracy than the strictest θ.
        let first = &rows[0];
        let last = &rows[rows.len() - 1];
        assert!(first.hit_rate() > last.hit_rate());
        assert!(
            first.positive_rate() <= last.positive_rate() + 1e-9,
            "accuracy should improve (or tie) as θ rises: {} vs {}",
            first.positive_rate(),
            last.positive_rate()
        );
    }
}
