//! The semantic cache — the paper's core contribution (§2).
//!
//! Workflow (paper §2.5/§2.8): a query embedding is searched in the ANN
//! index; if the best live neighbor clears the similarity threshold the
//! cached response is returned (hit), otherwise the caller fetches a
//! fresh response from the LLM and inserts it (miss). Entries carry TTL
//! (§2.7) and live in the Redis-substitute [`KvStore`]; the cache is
//! partitioned by embedding dimensionality (§2.3) so multiple embedding
//! models can coexist; tombstoned/expired index entries are reclaimed by
//! the periodic rebuild ("rebalancing", §2.4).

mod adaptive;
mod partition;

pub use adaptive::AdaptiveThreshold;
pub use partition::{EntryDump, Partition, PartitionDump};

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{bail, Result};
use crate::index::HnswConfig;
use crate::store::Clock;

/// Which ANN index backs each partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IndexKind {
    /// HNSW (paper's production choice).
    Hnsw,
    /// Exhaustive scan (paper's O(n) baseline).
    Flat,
}

/// Cache configuration (paper defaults).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Cosine similarity gate (paper §2.6: 0.8).
    pub threshold: f32,
    /// Entry TTL in ms (0 = immortal; paper §2.7).
    pub ttl_ms: u64,
    /// Max entries per partition (0 = unbounded, LRU beyond).
    pub capacity: usize,
    /// Neighbors fetched per lookup before thresholding.
    pub top_k: usize,
    pub index: IndexKind,
    pub hnsw: HnswConfig,
    /// Rebuild a partition's index when its tombstone ratio exceeds this.
    pub rebuild_garbage_ratio: f64,
    /// KV-store shards per partition.
    pub store_shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            threshold: 0.8,
            ttl_ms: 0,
            capacity: 0,
            top_k: 5,
            index: IndexKind::Hnsw,
            hnsw: HnswConfig::default(),
            rebuild_garbage_ratio: 0.3,
            store_shards: 16,
        }
    }
}

impl CacheConfig {
    /// A validating builder over the paper defaults:
    /// `CacheConfig::builder().threshold(0.85).build()?`.
    pub fn builder() -> CacheConfigBuilder {
        CacheConfigBuilder { cfg: CacheConfig::default() }
    }

    /// Assemble a validated cache config from the app-level
    /// [`crate::config::Config`] (shared by both binaries).
    pub fn from_app_config(cfg: &crate::config::Config) -> Result<CacheConfig> {
        CacheConfig::builder()
            .threshold(cfg.similarity_threshold)
            .ttl_ms(cfg.ttl_secs * 1000)
            .capacity(cfg.cache_capacity)
            .top_k(cfg.top_k)
            .index(match cfg.index_kind.as_str() {
                "flat" => IndexKind::Flat,
                _ => IndexKind::Hnsw,
            })
            .hnsw(HnswConfig {
                m: cfg.hnsw_m,
                ef_construction: cfg.hnsw_ef_construction,
                ef_search: cfg.hnsw_ef_search,
                ..HnswConfig::default()
            })
            .rebuild_garbage_ratio(cfg.rebuild_garbage_ratio)
            .store_shards(cfg.store_shards)
            .build()
    }

    /// Reject configurations the cache cannot serve correctly: NaN or
    /// out-of-range `threshold`/`rebuild_garbage_ratio`, `top_k == 0`,
    /// `store_shards == 0`.
    pub fn validate(&self) -> Result<()> {
        if !self.threshold.is_finite() || !(0.0..=1.0).contains(&self.threshold) {
            bail!("cache threshold must be a finite value in [0, 1], got {}", self.threshold);
        }
        if self.top_k == 0 {
            bail!("cache top_k must be >= 1");
        }
        if self.store_shards == 0 {
            bail!("cache store_shards must be >= 1");
        }
        if !self.rebuild_garbage_ratio.is_finite()
            || !(0.0..=1.0).contains(&self.rebuild_garbage_ratio)
        {
            bail!(
                "cache rebuild_garbage_ratio must be a finite value in [0, 1], got {}",
                self.rebuild_garbage_ratio
            );
        }
        Ok(())
    }
}

/// Builder for [`CacheConfig`]; `build` validates the result.
#[derive(Debug, Clone)]
pub struct CacheConfigBuilder {
    cfg: CacheConfig,
}

impl CacheConfigBuilder {
    pub fn threshold(mut self, threshold: f32) -> Self {
        self.cfg.threshold = threshold;
        self
    }

    pub fn ttl_ms(mut self, ttl_ms: u64) -> Self {
        self.cfg.ttl_ms = ttl_ms;
        self
    }

    pub fn capacity(mut self, capacity: usize) -> Self {
        self.cfg.capacity = capacity;
        self
    }

    pub fn top_k(mut self, top_k: usize) -> Self {
        self.cfg.top_k = top_k;
        self
    }

    pub fn index(mut self, index: IndexKind) -> Self {
        self.cfg.index = index;
        self
    }

    pub fn hnsw(mut self, hnsw: HnswConfig) -> Self {
        self.cfg.hnsw = hnsw;
        self
    }

    pub fn rebuild_garbage_ratio(mut self, ratio: f64) -> Self {
        self.cfg.rebuild_garbage_ratio = ratio;
        self
    }

    pub fn store_shards(mut self, shards: usize) -> Self {
        self.cfg.store_shards = shards;
        self
    }

    pub fn build(self) -> Result<CacheConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// A cached entry (what Redis holds in the paper).
#[derive(Debug, Clone)]
pub struct CachedEntry {
    pub question: String,
    pub response: String,
    /// Ground-truth answer-group id (carried for judge evaluation; a
    /// production deployment would not have this field).
    pub cluster: u64,
}

/// Observer of cache mutations, implemented by the persistence layer's
/// WAL ([`crate::persist::Persistence`]). Hooks fire *after* the mutation
/// is applied in memory — that ordering is what makes snapshot WAL
/// rotation race-free (any mutation applied after the snapshot's memory
/// capture necessarily lands in the post-rotation segment). Apply + log
/// happen under the cache's journal gate, so the WAL records dependent
/// mutations (a remove or clear racing an insert of the same id) in the
/// exact order they were applied — replay reproduces the applied
/// history, never an inverted one. The journal is attached only after
/// recovery replay, so replayed mutations are never re-logged.
pub trait CacheJournal: Send + Sync {
    /// A new entry: its partition dim, assigned id, raw (unnormalized)
    /// embedding, payload, and absolute wall-clock expiry
    /// (`u64::MAX` = immortal).
    fn log_insert(
        &self,
        dim: usize,
        id: u64,
        embedding: &[f32],
        entry: &CachedEntry,
        expires_wall_ms: u64,
    );
    /// An explicit removal of entry `id` in partition `dim`.
    fn log_remove(&self, dim: usize, id: u64);
    /// A full flush (`/v1/admin` flush).
    fn log_clear(&self);
}

/// A successful lookup.
#[derive(Debug, Clone)]
pub struct CacheHit {
    pub entry: CachedEntry,
    /// Cosine similarity of the matched embedding.
    pub score: f32,
    /// Internal id of the matched entry.
    pub id: u64,
}

/// Dimension-partitioned semantic cache. All methods take `&self`; the
/// partition map and each partition's ANN index are behind read-mostly
/// `RwLock`s, so concurrent lookups (the batch serving fan-out) share
/// the locks and search in parallel; only inserts, tombstoning, and
/// rebuilds serialize on the write side.
pub struct SemanticCache {
    cfg: CacheConfig,
    partitions: std::sync::RwLock<HashMap<usize, Arc<Partition>>>,
    clock: Arc<dyn Clock>,
    /// Mutation observer (WAL); `None` until durability is enabled.
    journal: std::sync::RwLock<Option<Arc<dyn CacheJournal>>>,
    /// Serializes journaled mutations across apply + log so WAL order
    /// always matches in-memory apply order (without it, a remove or
    /// clear racing an insert of the same id could log before the
    /// insert's record, and replay would resurrect the removed entry or
    /// drop an acknowledged one). Uncontended when durability is off —
    /// the non-journal paths never take it.
    journal_gate: std::sync::Mutex<()>,
}

impl SemanticCache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self::with_clock(cfg, Arc::new(crate::store::SystemClock))
    }

    pub fn with_clock(cfg: CacheConfig, clock: Arc<dyn Clock>) -> Self {
        Self {
            cfg,
            partitions: std::sync::RwLock::new(HashMap::new()),
            clock,
            journal: std::sync::RwLock::new(None),
            journal_gate: std::sync::Mutex::new(()),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The clock this cache (and its partitions' stores) runs on.
    pub fn clock(&self) -> Arc<dyn Clock> {
        self.clock.clone()
    }

    /// Attach a mutation journal. Called after recovery replay so that
    /// replayed mutations are not logged a second time.
    pub fn set_journal(&self, journal: Arc<dyn CacheJournal>) {
        *self.journal.write().unwrap() = Some(journal);
    }

    fn journal(&self) -> Option<Arc<dyn CacheJournal>> {
        self.journal.read().unwrap().clone()
    }

    /// All populated partitions (snapshot/recovery iteration order is
    /// made deterministic by sorting on dim).
    pub fn partitions(&self) -> Vec<Arc<Partition>> {
        let mut parts: Vec<Arc<Partition>> =
            self.partitions.read().unwrap().values().cloned().collect();
        parts.sort_by_key(|p| p.dim());
        parts
    }

    /// The partition for a given embedding size, created on first use
    /// (paper §2.3: "the cache is partitioned based on the embedding
    /// size"). Double-checked read-then-write: the common case (the
    /// partition exists) never takes the exclusive lock.
    pub fn partition(&self, dim: usize) -> Arc<Partition> {
        if let Some(p) = self.partitions.read().unwrap().get(&dim) {
            return p.clone();
        }
        let mut parts = self.partitions.write().unwrap();
        parts
            .entry(dim)
            .or_insert_with(|| Arc::new(Partition::new(dim, &self.cfg, self.clock.clone())))
            .clone()
    }

    /// The partition for `dim` if one has been populated, without the
    /// side effect of creating it.
    pub fn partition_if_exists(&self, dim: usize) -> Option<Arc<Partition>> {
        self.partitions.read().unwrap().get(&dim).cloned()
    }

    /// Lookup with the configured threshold.
    pub fn lookup(&self, embedding: &[f32]) -> Option<CacheHit> {
        self.lookup_with_threshold(embedding, self.cfg.threshold)
    }

    /// Lookup with an explicit threshold (threshold-sweep experiments).
    ///
    /// Empty embeddings and unpopulated partitions miss cleanly (no
    /// partition is allocated as a lookup side effect).
    pub fn lookup_with_threshold(&self, embedding: &[f32], threshold: f32) -> Option<CacheHit> {
        self.lookup_with_opts(embedding, threshold, None)
    }

    /// Lookup with per-request threshold and (optionally) top-k — the
    /// entry point used by the typed serving API.
    pub fn lookup_with_opts(
        &self,
        embedding: &[f32],
        threshold: f32,
        top_k: Option<usize>,
    ) -> Option<CacheHit> {
        if embedding.is_empty() {
            return None;
        }
        self.partition_if_exists(embedding.len())?.lookup_k(embedding, threshold, top_k)
    }

    /// Insert a question/response pair under its embedding; returns the
    /// new entry's id.
    pub fn try_insert(&self, question: &str, embedding: &[f32], response: &str) -> Result<u64> {
        self.try_insert_entry(
            embedding,
            CachedEntry {
                question: question.to_string(),
                response: response.to_string(),
                cluster: 0,
            },
        )
    }

    /// Insert an entry under the configured TTL; returns its id.
    pub fn try_insert_entry(&self, embedding: &[f32], entry: CachedEntry) -> Result<u64> {
        self.try_insert_entry_ttl(embedding, entry, None)
    }

    /// Insert an entry with a per-entry TTL override (`None` = the
    /// configured default, `Some(0)` = immortal); returns its id.
    pub fn try_insert_entry_ttl(
        &self,
        embedding: &[f32],
        entry: CachedEntry,
        ttl_ms: Option<u64>,
    ) -> Result<u64> {
        if embedding.is_empty() {
            bail!("cannot insert an empty embedding");
        }
        match self.journal() {
            None => Ok(self.partition(embedding.len()).insert_with_ttl(embedding, entry, ttl_ms)),
            Some(journal) => {
                // Apply first, then log, with the journal gate held
                // across both (see [`CacheJournal`] ordering). The
                // partition is resolved inside the gate so a racing
                // `clear` cannot detach it between apply and log.
                let _order = self.journal_gate.lock().unwrap();
                let p = self.partition(embedding.len());
                let id = p.insert_with_ttl(embedding, entry.clone(), ttl_ms);
                let ttl = ttl_ms.unwrap_or(self.cfg.ttl_ms);
                let expires_wall_ms =
                    if ttl == 0 { u64::MAX } else { self.clock.wall_ms() + ttl };
                journal.log_insert(embedding.len(), id, embedding, &entry, expires_wall_ms);
                Ok(id)
            }
        }
    }

    /// Remove one entry by partition dim and id (store, index, and
    /// embedding map together). Returns whether a live entry was removed.
    pub fn remove_entry(&self, dim: usize, id: u64) -> bool {
        match self.journal() {
            None => self.partition_if_exists(dim).map_or(false, |p| p.remove_id(id)),
            Some(journal) => {
                let _order = self.journal_gate.lock().unwrap();
                let Some(p) = self.partition_if_exists(dim) else {
                    return false;
                };
                let removed = p.remove_id(id);
                if removed {
                    journal.log_remove(dim, id);
                }
                removed
            }
        }
    }

    /// Pre-v1 insert with the `0 = rejected` sentinel.
    #[deprecated(since = "0.2.0", note = "use try_insert, which reports rejection as an error")]
    pub fn insert(&self, question: &str, embedding: &[f32], response: &str) -> u64 {
        self.try_insert(question, embedding, response).unwrap_or(0)
    }

    /// Pre-v1 entry insert with the `0 = rejected` sentinel (never a
    /// real id — ids start at 1).
    #[deprecated(
        since = "0.2.0",
        note = "use try_insert_entry, which reports rejection as an error"
    )]
    pub fn insert_entry(&self, embedding: &[f32], entry: CachedEntry) -> u64 {
        self.try_insert_entry(embedding, entry).unwrap_or(0)
    }

    /// Drop every entry and partition. Returns the number of live
    /// entries removed (the `/v1/admin` flush operation).
    pub fn clear(&self) -> usize {
        let _order = self.journal().map(|_| self.journal_gate.lock().unwrap());
        let removed = {
            let mut parts = self.partitions.write().unwrap();
            let removed = parts.values().map(|p| p.len()).sum();
            parts.clear();
            removed
        };
        if let Some(journal) = self.journal() {
            journal.log_clear();
        }
        removed
    }

    /// Total live entries across partitions.
    pub fn len(&self) -> usize {
        let parts = self.partitions.read().unwrap();
        parts.values().map(|p| p.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Housekeeping pass: sweep expired entries and rebuild indexes whose
    /// garbage ratio exceeds the configured bound. Returns (expired,
    /// rebuilt-partition count). Driven by the coordinator's timer.
    pub fn housekeep(&self) -> (usize, usize) {
        let parts: Vec<Arc<Partition>> =
            self.partitions.read().unwrap().values().cloned().collect();
        let mut expired = 0;
        let mut rebuilt = 0;
        for p in parts {
            expired += p.sweep_expired();
            if p.garbage_ratio() > self.cfg.rebuild_garbage_ratio && p.rebuild() {
                rebuilt += 1;
            }
        }
        (expired, rebuilt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ManualClock;

    fn unit(dim: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = 1.0;
        v
    }

    /// A vector leaning toward axis `hot` with a controlled cosine.
    fn near(dim: usize, hot: usize, cos: f32) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = cos;
        v[(hot + 1) % dim] = (1.0 - cos * cos).sqrt();
        v
    }

    #[test]
    fn miss_insert_hit_workflow() {
        let cache = SemanticCache::new(CacheConfig::default());
        let e = unit(16, 3);
        assert!(cache.lookup(&e).is_none());
        cache.try_insert("q", &e, "r").unwrap();
        let hit = cache.lookup(&e).expect("exact match hits");
        assert_eq!(hit.entry.response, "r");
        assert!(hit.score > 0.999);
    }

    #[test]
    fn threshold_gates_hits() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("q", &unit(16, 0), "r").unwrap();
        // cos 0.9 passes the 0.8 gate; cos 0.7 does not.
        assert!(cache.lookup(&near(16, 0, 0.9)).is_some());
        assert!(cache.lookup(&near(16, 0, 0.7)).is_none());
        // but a lenient explicit threshold accepts it.
        assert!(cache.lookup_with_threshold(&near(16, 0, 0.7), 0.6).is_some());
    }

    #[test]
    fn partitions_by_dim_are_independent() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("a", &unit(16, 0), "r16").unwrap();
        cache.try_insert("b", &unit(32, 0), "r32").unwrap();
        assert_eq!(cache.len(), 2);
        let hit = cache.lookup(&unit(32, 0)).unwrap();
        assert_eq!(hit.entry.response, "r32");
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "r16");
    }

    #[test]
    fn ttl_expires_entries() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 1_000, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        let e = unit(8, 2);
        cache.try_insert("q", &e, "r").unwrap();
        assert!(cache.lookup(&e).is_some());
        clock.advance(1_500);
        assert!(cache.lookup(&e).is_none(), "expired entry must not hit");
        // Sweep reclaims both store and (after rebuild check) index slots.
        let (expired, _) = cache.housekeep();
        // The lazy lookup above already dropped it from the store; sweep
        // finds zero or counts it once depending on timing — both fine,
        // but len() must be 0 either way.
        let _ = expired;
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn empty_embedding_and_unpopulated_partition_miss_cleanly() {
        let cache = SemanticCache::new(CacheConfig::default());
        // Empty embedding: lookup misses, insert is a typed rejection —
        // and the deprecated sentinel shim still reports it as 0.
        assert!(cache.lookup(&[]).is_none());
        assert!(cache.try_insert("q", &[], "r").is_err());
        #[allow(deprecated)]
        {
            assert_eq!(cache.insert("q", &[], "r"), 0);
        }
        assert_eq!(cache.len(), 0);
        // Lookup against a dimension that was never populated must miss
        // without allocating a partition as a side effect.
        assert!(cache.lookup(&unit(24, 0)).is_none());
        assert!(cache.partition_if_exists(24).is_none());
        // A real insert then behaves normally.
        cache.try_insert("q", &unit(24, 0), "r").unwrap();
        assert!(cache.partition_if_exists(24).is_some());
        assert!(cache.lookup(&unit(24, 0)).is_some());
    }

    #[test]
    fn best_of_multiple_candidates_wins() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("far", &near(16, 0, 0.85), "far-r").unwrap();
        cache.try_insert("near", &unit(16, 0), "near-r").unwrap();
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "near-r");
    }

    #[test]
    fn housekeep_rebuilds_garbage_heavy_partition() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 100, rebuild_garbage_ratio: 0.2, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        for i in 0..50 {
            cache.try_insert(&format!("q{i}"), &near(16, i % 16, 0.99), "r").unwrap();
        }
        clock.advance(200);
        let (expired, rebuilt) = cache.housekeep();
        assert_eq!(expired, 50);
        assert_eq!(rebuilt, 1, "all entries dead -> garbage ratio 1.0 -> rebuild");
        assert_eq!(cache.len(), 0);
        // Cache still works after rebuild.
        cache.try_insert("fresh", &unit(16, 5), "fr").unwrap();
        clock.advance(50);
        assert!(cache.lookup(&unit(16, 5)).is_some());
    }

    #[test]
    fn builder_accepts_valid_and_rejects_invalid() {
        let cfg = CacheConfig::builder()
            .threshold(0.85)
            .ttl_ms(1_000)
            .capacity(100)
            .top_k(3)
            .index(IndexKind::Flat)
            .rebuild_garbage_ratio(0.5)
            .store_shards(4)
            .build()
            .unwrap();
        assert_eq!(cfg.threshold, 0.85);
        assert_eq!(cfg.top_k, 3);
        assert_eq!(cfg.index, IndexKind::Flat);

        assert!(CacheConfig::builder().threshold(f32::NAN).build().is_err(), "NaN threshold");
        assert!(CacheConfig::builder().threshold(1.5).build().is_err(), "threshold > 1");
        assert!(CacheConfig::builder().threshold(-0.1).build().is_err(), "threshold < 0");
        assert!(CacheConfig::builder().top_k(0).build().is_err(), "top_k == 0");
        assert!(CacheConfig::builder().store_shards(0).build().is_err(), "store_shards == 0");
        assert!(
            CacheConfig::builder().rebuild_garbage_ratio(f64::NAN).build().is_err(),
            "NaN garbage ratio"
        );
        assert!(
            CacheConfig::builder().rebuild_garbage_ratio(2.0).build().is_err(),
            "garbage ratio > 1"
        );
    }

    #[test]
    fn clear_drops_all_partitions() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("a", &unit(16, 0), "r16").unwrap();
        cache.try_insert("b", &unit(32, 0), "r32").unwrap();
        assert_eq!(cache.clear(), 2);
        assert_eq!(cache.len(), 0);
        assert!(cache.lookup(&unit(16, 0)).is_none());
        // The cache keeps serving after a flush.
        cache.try_insert("c", &unit(16, 1), "again").unwrap();
        assert!(cache.lookup(&unit(16, 1)).is_some());
    }

    #[test]
    fn per_entry_ttl_overrides_config_default() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 10_000, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        let short = unit(8, 0);
        let default = unit(8, 2);
        let immortal = unit(8, 4);
        let mk = |q: &str| CachedEntry { question: q.into(), response: q.into(), cluster: 0 };
        cache.try_insert_entry_ttl(&short, mk("short"), Some(500)).unwrap();
        cache.try_insert_entry_ttl(&default, mk("default"), None).unwrap();
        cache.try_insert_entry_ttl(&immortal, mk("immortal"), Some(0)).unwrap();
        clock.advance(1_000);
        assert!(cache.lookup(&short).is_none(), "short-TTL entry must expire first");
        assert!(cache.lookup(&default).is_some());
        clock.advance(20_000);
        assert!(cache.lookup(&default).is_none(), "config default TTL still applies");
        assert!(cache.lookup(&immortal).is_some(), "ttl 0 pins the entry");
    }

    #[test]
    fn per_request_top_k_overrides_config() {
        // Config top_k = 1 and a per-request override of 5 must agree on
        // the best-scoring hit (the override widens the candidate set,
        // never changes the winner).
        let cfg = CacheConfig { index: IndexKind::Flat, top_k: 1, ..Default::default() };
        let cache = SemanticCache::new(cfg);
        cache.try_insert("best", &unit(16, 0), "best-r").unwrap();
        cache.try_insert("other", &near(16, 0, 0.9), "other-r").unwrap();
        // Default (config) top_k = 1: best match wins.
        let hit = cache.lookup_with_opts(&unit(16, 0), 0.8, None).unwrap();
        assert_eq!(hit.entry.response, "best-r");
        // Per-request top_k = 5 must behave identically for the best hit.
        let hit = cache.lookup_with_opts(&unit(16, 0), 0.8, Some(5)).unwrap();
        assert_eq!(hit.entry.response, "best-r");
    }
}
