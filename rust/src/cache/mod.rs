//! The semantic cache — the paper's core contribution (§2).
//!
//! Workflow (paper §2.5/§2.8): a query embedding is searched in the ANN
//! index; if the best live neighbor clears the similarity threshold the
//! cached response is returned (hit), otherwise the caller fetches a
//! fresh response from the LLM and inserts it (miss). Entries carry TTL
//! (§2.7) and live in the Redis-substitute [`KvStore`]; the cache is
//! partitioned by embedding dimensionality (§2.3) so multiple embedding
//! models can coexist; tombstoned/expired index entries are reclaimed by
//! the periodic rebuild ("rebalancing", §2.4).

mod adaptive;
mod partition;

pub use adaptive::AdaptiveThreshold;
pub use partition::Partition;

use std::collections::HashMap;
use std::sync::Arc;

use crate::index::HnswConfig;
use crate::store::Clock;

/// Which ANN index backs each partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IndexKind {
    /// HNSW (paper's production choice).
    Hnsw,
    /// Exhaustive scan (paper's O(n) baseline).
    Flat,
}

/// Cache configuration (paper defaults).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Cosine similarity gate (paper §2.6: 0.8).
    pub threshold: f32,
    /// Entry TTL in ms (0 = immortal; paper §2.7).
    pub ttl_ms: u64,
    /// Max entries per partition (0 = unbounded, LRU beyond).
    pub capacity: usize,
    /// Neighbors fetched per lookup before thresholding.
    pub top_k: usize,
    pub index: IndexKind,
    pub hnsw: HnswConfig,
    /// Rebuild a partition's index when its tombstone ratio exceeds this.
    pub rebuild_garbage_ratio: f64,
    /// KV-store shards per partition.
    pub store_shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            threshold: 0.8,
            ttl_ms: 0,
            capacity: 0,
            top_k: 5,
            index: IndexKind::Hnsw,
            hnsw: HnswConfig::default(),
            rebuild_garbage_ratio: 0.3,
            store_shards: 16,
        }
    }
}

/// A cached entry (what Redis holds in the paper).
#[derive(Debug, Clone)]
pub struct CachedEntry {
    pub question: String,
    pub response: String,
    /// Ground-truth answer-group id (carried for judge evaluation; a
    /// production deployment would not have this field).
    pub cluster: u64,
}

/// A successful lookup.
#[derive(Debug, Clone)]
pub struct CacheHit {
    pub entry: CachedEntry,
    /// Cosine similarity of the matched embedding.
    pub score: f32,
    /// Internal id of the matched entry.
    pub id: u64,
}

/// Dimension-partitioned semantic cache. All methods take `&self`; the
/// partition map and each partition's ANN index are behind read-mostly
/// `RwLock`s, so concurrent lookups (the batch serving fan-out) share
/// the locks and search in parallel; only inserts, tombstoning, and
/// rebuilds serialize on the write side.
pub struct SemanticCache {
    cfg: CacheConfig,
    partitions: std::sync::RwLock<HashMap<usize, Arc<Partition>>>,
    clock: Arc<dyn Clock>,
}

impl SemanticCache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self::with_clock(cfg, Arc::new(crate::store::SystemClock))
    }

    pub fn with_clock(cfg: CacheConfig, clock: Arc<dyn Clock>) -> Self {
        Self { cfg, partitions: std::sync::RwLock::new(HashMap::new()), clock }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The partition for a given embedding size, created on first use
    /// (paper §2.3: "the cache is partitioned based on the embedding
    /// size"). Double-checked read-then-write: the common case (the
    /// partition exists) never takes the exclusive lock.
    pub fn partition(&self, dim: usize) -> Arc<Partition> {
        if let Some(p) = self.partitions.read().unwrap().get(&dim) {
            return p.clone();
        }
        let mut parts = self.partitions.write().unwrap();
        parts
            .entry(dim)
            .or_insert_with(|| Arc::new(Partition::new(dim, &self.cfg, self.clock.clone())))
            .clone()
    }

    /// The partition for `dim` if one has been populated, without the
    /// side effect of creating it.
    pub fn partition_if_exists(&self, dim: usize) -> Option<Arc<Partition>> {
        self.partitions.read().unwrap().get(&dim).cloned()
    }

    /// Lookup with the configured threshold.
    pub fn lookup(&self, embedding: &[f32]) -> Option<CacheHit> {
        self.lookup_with_threshold(embedding, self.cfg.threshold)
    }

    /// Lookup with an explicit threshold (threshold-sweep experiments).
    ///
    /// Empty embeddings and unpopulated partitions miss cleanly (no
    /// partition is allocated as a lookup side effect).
    pub fn lookup_with_threshold(&self, embedding: &[f32], threshold: f32) -> Option<CacheHit> {
        if embedding.is_empty() {
            return None;
        }
        self.partition_if_exists(embedding.len())?.lookup(embedding, threshold)
    }

    /// Insert a question/response pair under its embedding.
    pub fn insert(&self, question: &str, embedding: &[f32], response: &str) -> u64 {
        self.insert_entry(
            embedding,
            CachedEntry {
                question: question.to_string(),
                response: response.to_string(),
                cluster: 0,
            },
        )
    }

    /// Insert an entry; returns its id. Empty embeddings are rejected as
    /// a no-op returning 0 (never a real id — ids start at 1).
    pub fn insert_entry(&self, embedding: &[f32], entry: CachedEntry) -> u64 {
        if embedding.is_empty() {
            return 0;
        }
        self.partition(embedding.len()).insert(embedding, entry)
    }

    /// Total live entries across partitions.
    pub fn len(&self) -> usize {
        let parts = self.partitions.read().unwrap();
        parts.values().map(|p| p.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Housekeeping pass: sweep expired entries and rebuild indexes whose
    /// garbage ratio exceeds the configured bound. Returns (expired,
    /// rebuilt-partition count). Driven by the coordinator's timer.
    pub fn housekeep(&self) -> (usize, usize) {
        let parts: Vec<Arc<Partition>> =
            self.partitions.read().unwrap().values().cloned().collect();
        let mut expired = 0;
        let mut rebuilt = 0;
        for p in parts {
            expired += p.sweep_expired();
            if p.garbage_ratio() > self.cfg.rebuild_garbage_ratio && p.rebuild() {
                rebuilt += 1;
            }
        }
        (expired, rebuilt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ManualClock;

    fn unit(dim: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = 1.0;
        v
    }

    /// A vector leaning toward axis `hot` with a controlled cosine.
    fn near(dim: usize, hot: usize, cos: f32) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = cos;
        v[(hot + 1) % dim] = (1.0 - cos * cos).sqrt();
        v
    }

    #[test]
    fn miss_insert_hit_workflow() {
        let cache = SemanticCache::new(CacheConfig::default());
        let e = unit(16, 3);
        assert!(cache.lookup(&e).is_none());
        cache.insert("q", &e, "r");
        let hit = cache.lookup(&e).expect("exact match hits");
        assert_eq!(hit.entry.response, "r");
        assert!(hit.score > 0.999);
    }

    #[test]
    fn threshold_gates_hits() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.insert("q", &unit(16, 0), "r");
        // cos 0.9 passes the 0.8 gate; cos 0.7 does not.
        assert!(cache.lookup(&near(16, 0, 0.9)).is_some());
        assert!(cache.lookup(&near(16, 0, 0.7)).is_none());
        // but a lenient explicit threshold accepts it.
        assert!(cache.lookup_with_threshold(&near(16, 0, 0.7), 0.6).is_some());
    }

    #[test]
    fn partitions_by_dim_are_independent() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.insert("a", &unit(16, 0), "r16");
        cache.insert("b", &unit(32, 0), "r32");
        assert_eq!(cache.len(), 2);
        let hit = cache.lookup(&unit(32, 0)).unwrap();
        assert_eq!(hit.entry.response, "r32");
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "r16");
    }

    #[test]
    fn ttl_expires_entries() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 1_000, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        let e = unit(8, 2);
        cache.insert("q", &e, "r");
        assert!(cache.lookup(&e).is_some());
        clock.advance(1_500);
        assert!(cache.lookup(&e).is_none(), "expired entry must not hit");
        // Sweep reclaims both store and (after rebuild check) index slots.
        let (expired, _) = cache.housekeep();
        // The lazy lookup above already dropped it from the store; sweep
        // finds zero or counts it once depending on timing — both fine,
        // but len() must be 0 either way.
        let _ = expired;
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn empty_embedding_and_unpopulated_partition_miss_cleanly() {
        let cache = SemanticCache::new(CacheConfig::default());
        // Empty embedding: lookup misses, insert is a rejected no-op.
        assert!(cache.lookup(&[]).is_none());
        assert_eq!(cache.insert("q", &[], "r"), 0);
        assert_eq!(cache.len(), 0);
        // Lookup against a dimension that was never populated must miss
        // without allocating a partition as a side effect.
        assert!(cache.lookup(&unit(24, 0)).is_none());
        assert!(cache.partition_if_exists(24).is_none());
        // A real insert then behaves normally.
        cache.insert("q", &unit(24, 0), "r");
        assert!(cache.partition_if_exists(24).is_some());
        assert!(cache.lookup(&unit(24, 0)).is_some());
    }

    #[test]
    fn best_of_multiple_candidates_wins() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.insert("far", &near(16, 0, 0.85), "far-r");
        cache.insert("near", &unit(16, 0), "near-r");
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "near-r");
    }

    #[test]
    fn housekeep_rebuilds_garbage_heavy_partition() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 100, rebuild_garbage_ratio: 0.2, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        for i in 0..50 {
            cache.insert(&format!("q{i}"), &near(16, i % 16, 0.99), "r");
        }
        clock.advance(200);
        let (expired, rebuilt) = cache.housekeep();
        assert_eq!(expired, 50);
        assert_eq!(rebuilt, 1, "all entries dead -> garbage ratio 1.0 -> rebuild");
        assert_eq!(cache.len(), 0);
        // Cache still works after rebuild.
        cache.insert("fresh", &unit(16, 5), "fr");
        clock.advance(50);
        assert!(cache.lookup(&unit(16, 5)).is_some());
    }
}
