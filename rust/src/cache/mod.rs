//! The semantic cache — the paper's core contribution (§2).
//!
//! Workflow (paper §2.5/§2.8): a query embedding is searched in the ANN
//! index; if the best live neighbor clears the similarity threshold the
//! cached response is returned (hit), otherwise the caller fetches a
//! fresh response from the LLM and inserts it (miss). Entries carry TTL
//! (§2.7) and live in the Redis-substitute [`KvStore`]; the cache is
//! partitioned by embedding dimensionality (§2.3) so multiple embedding
//! models can coexist; tombstoned/expired index entries are reclaimed by
//! the periodic rebuild ("rebalancing", §2.4).
//!
//! # Tenancy and byte budgets
//!
//! The cache is namespaced by **tenant** (the serving API's
//! `client_tag`): partitions are keyed on (tenant, dim), so a lookup can
//! only ever see entries its own tenant inserted — cross-tenant reads
//! are structurally impossible, not filtered. Memory is accounted in
//! **bytes**, not entry counts: every entry charges its real footprint
//! ([`crate::eviction::entry_footprint`] — question + response +
//! embedding copies + index-node estimate) against an optional global
//! budget ([`CacheConfig::max_bytes`]) and an optional per-tenant quota
//! ([`CacheConfig::tenant_quota_bytes`], overridable per tenant).
//! Budgets are enforced **inserter-pays**: the insert that pushes a
//! tenant over its quota (or the cache over its global budget) evicts
//! the lowest-scoring entries *of that tenant* — chosen by the
//! configured [`crate::eviction::EvictionPolicy`] — until the budgets
//! hold again. A hot tenant can therefore never evict a cold tenant's
//! working set, and the global budget can transiently overshoot by at
//! most one entry footprint.
//!
//! [`KvStore`]: crate::store::KvStore

mod adaptive;
mod partition;

pub use adaptive::AdaptiveThreshold;
pub use partition::{EntryDump, Partition, PartitionDump, PartitionVictim};

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{bail, Result};
use crate::eviction::{entry_footprint, policy_from_name, EvictionPolicy};
use crate::index::HnswConfig;
use crate::store::Clock;
use crate::tenancy::{TenantOverrides, TenantState, TenantStats, DEFAULT_TENANT};

/// Which ANN index backs each partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IndexKind {
    /// HNSW (paper's production choice).
    Hnsw,
    /// Exhaustive scan (paper's O(n) baseline).
    Flat,
}

/// Cache configuration (paper defaults).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Cosine similarity gate (paper §2.6: 0.8).
    pub threshold: f32,
    /// Entry TTL in ms (0 = immortal; paper §2.7).
    pub ttl_ms: u64,
    /// Legacy count bound per partition (0 = unbounded, LRU beyond).
    /// Superseded by [`Self::max_bytes`]; kept for tests and embedded
    /// use — the app-config path clamps it to 0 (see
    /// [`Self::from_app_config`]).
    pub capacity: usize,
    /// Neighbors fetched per lookup before thresholding.
    pub top_k: usize,
    pub index: IndexKind,
    pub hnsw: HnswConfig,
    /// Score ANN candidates through the int8 code matrix (exact-f32
    /// rerank of survivors) instead of full f32 dots. Returned scores
    /// are exact either way; `SEMCACHE_SCALAR_KERNELS=1` forces the
    /// exact path at runtime regardless of this flag.
    pub quantized_scan: bool,
    /// Rebuild a partition's index when its tombstone ratio exceeds this.
    pub rebuild_garbage_ratio: f64,
    /// KV-store shards per partition.
    pub store_shards: usize,
    /// Global byte budget across every tenant and partition (0 =
    /// unbounded). Enforced inserter-pays: the tenant whose insert
    /// breaches it evicts its own entries.
    pub max_bytes: u64,
    /// Which entries the byte budgets sacrifice first: "lru", "lfu", or
    /// "cost" (simulated LLM latency saved per byte — evicts the
    /// cheapest-to-recompute bytes first).
    pub eviction_policy: String,
    /// Default per-tenant byte quota (0 = unbounded); individual tenants
    /// can override via [`Self::tenants`].
    pub tenant_quota_bytes: u64,
    /// Per-tenant overrides (quota, similarity threshold), keyed by
    /// tenant name.
    pub tenants: BTreeMap<String, TenantOverrides>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            threshold: 0.8,
            ttl_ms: 0,
            capacity: 0,
            top_k: 5,
            index: IndexKind::Hnsw,
            hnsw: HnswConfig::default(),
            quantized_scan: true,
            rebuild_garbage_ratio: 0.3,
            store_shards: 16,
            max_bytes: 0,
            eviction_policy: "lru".to_string(),
            tenant_quota_bytes: 0,
            tenants: BTreeMap::new(),
        }
    }
}

impl CacheConfig {
    /// A validating builder over the paper defaults:
    /// `CacheConfig::builder().threshold(0.85).build()?`.
    pub fn builder() -> CacheConfigBuilder {
        CacheConfigBuilder { cfg: CacheConfig::default() }
    }

    /// Assemble a validated cache config from the app-level
    /// [`crate::config::Config`] (shared by both binaries).
    ///
    /// Migration note: the legacy count-based `cache_capacity` key is
    /// accepted but clamped to 0 (unbounded) here — byte-accurate
    /// budgets (`max_bytes`, `tenant_quota_bytes`) replaced it. The key
    /// is not rejected so that pre-byte-budget config files keep
    /// loading.
    pub fn from_app_config(cfg: &crate::config::Config) -> Result<CacheConfig> {
        CacheConfig::builder()
            .threshold(cfg.similarity_threshold)
            .ttl_ms(cfg.ttl_secs * 1000)
            .top_k(cfg.top_k)
            .index(match cfg.index_kind.as_str() {
                "flat" => IndexKind::Flat,
                _ => IndexKind::Hnsw,
            })
            .hnsw(HnswConfig {
                m: cfg.hnsw_m,
                ef_construction: cfg.hnsw_ef_construction,
                ef_search: cfg.hnsw_ef_search,
                ..HnswConfig::default()
            })
            .quantized_scan(cfg.quantized_scan)
            .rebuild_garbage_ratio(cfg.rebuild_garbage_ratio)
            .store_shards(cfg.store_shards)
            .max_bytes(cfg.max_bytes)
            .eviction_policy(&cfg.eviction_policy)
            .tenant_quota_bytes(cfg.tenant_quota_bytes)
            .tenants(cfg.tenants.clone())
            .build()
    }

    /// Reject configurations the cache cannot serve correctly: NaN or
    /// out-of-range `threshold`/`rebuild_garbage_ratio`, `top_k == 0`,
    /// `store_shards == 0`, unknown `eviction_policy`, or an
    /// out-of-range per-tenant threshold override.
    pub fn validate(&self) -> Result<()> {
        if !self.threshold.is_finite() || !(0.0..=1.0).contains(&self.threshold) {
            bail!("cache threshold must be a finite value in [0, 1], got {}", self.threshold);
        }
        if self.top_k == 0 {
            bail!("cache top_k must be >= 1");
        }
        if self.store_shards == 0 {
            bail!("cache store_shards must be >= 1");
        }
        if !self.rebuild_garbage_ratio.is_finite()
            || !(0.0..=1.0).contains(&self.rebuild_garbage_ratio)
        {
            bail!(
                "cache rebuild_garbage_ratio must be a finite value in [0, 1], got {}",
                self.rebuild_garbage_ratio
            );
        }
        policy_from_name(&self.eviction_policy)?;
        for (name, o) in &self.tenants {
            if let Some(th) = o.similarity_threshold {
                if !th.is_finite() || !(0.0..=1.0).contains(&th) {
                    bail!(
                        "tenant '{name}' similarity_threshold must be a finite value in [0, 1], got {th}"
                    );
                }
            }
        }
        Ok(())
    }
}

/// Builder for [`CacheConfig`]; `build` validates the result.
#[derive(Debug, Clone)]
pub struct CacheConfigBuilder {
    cfg: CacheConfig,
}

impl CacheConfigBuilder {
    pub fn threshold(mut self, threshold: f32) -> Self {
        self.cfg.threshold = threshold;
        self
    }

    pub fn ttl_ms(mut self, ttl_ms: u64) -> Self {
        self.cfg.ttl_ms = ttl_ms;
        self
    }

    pub fn capacity(mut self, capacity: usize) -> Self {
        self.cfg.capacity = capacity;
        self
    }

    pub fn top_k(mut self, top_k: usize) -> Self {
        self.cfg.top_k = top_k;
        self
    }

    pub fn index(mut self, index: IndexKind) -> Self {
        self.cfg.index = index;
        self
    }

    pub fn hnsw(mut self, hnsw: HnswConfig) -> Self {
        self.cfg.hnsw = hnsw;
        self
    }

    pub fn quantized_scan(mut self, on: bool) -> Self {
        self.cfg.quantized_scan = on;
        self
    }

    pub fn rebuild_garbage_ratio(mut self, ratio: f64) -> Self {
        self.cfg.rebuild_garbage_ratio = ratio;
        self
    }

    pub fn store_shards(mut self, shards: usize) -> Self {
        self.cfg.store_shards = shards;
        self
    }

    pub fn max_bytes(mut self, max_bytes: u64) -> Self {
        self.cfg.max_bytes = max_bytes;
        self
    }

    pub fn eviction_policy(mut self, policy: &str) -> Self {
        self.cfg.eviction_policy = policy.to_string();
        self
    }

    pub fn tenant_quota_bytes(mut self, quota: u64) -> Self {
        self.cfg.tenant_quota_bytes = quota;
        self
    }

    /// Install per-tenant overrides wholesale.
    pub fn tenants(mut self, tenants: BTreeMap<String, TenantOverrides>) -> Self {
        self.cfg.tenants = tenants;
        self
    }

    /// Add or replace one tenant's overrides.
    pub fn tenant(mut self, name: &str, overrides: TenantOverrides) -> Self {
        self.cfg.tenants.insert(name.to_string(), overrides);
        self
    }

    pub fn build(self) -> Result<CacheConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// A cached entry (what Redis holds in the paper).
#[derive(Debug, Clone)]
pub struct CachedEntry {
    pub question: String,
    pub response: String,
    /// Ground-truth answer-group id (carried for judge evaluation; a
    /// production deployment would not have this field).
    pub cluster: u64,
    /// Upstream LLM latency this entry saves per hit (ms) — the value
    /// signal for the cost-aware eviction policy. 0 when unknown.
    pub latency_ms: f64,
}

/// Observer of cache mutations, implemented by the persistence layer's
/// WAL ([`crate::persist::Persistence`]). Hooks fire *after* the mutation
/// is applied in memory — that ordering is what makes snapshot WAL
/// rotation race-free (any mutation applied after the snapshot's memory
/// capture necessarily lands in the post-rotation segment). Apply + log
/// happen under the cache's journal gate, so the WAL records dependent
/// mutations (a remove or clear racing an insert of the same id) in the
/// exact order they were applied — replay reproduces the applied
/// history, never an inverted one. The journal is attached only after
/// recovery replay, so replayed mutations are never re-logged.
pub trait CacheJournal: Send + Sync {
    /// A new entry: its tenant, partition dim, assigned id, raw
    /// (unnormalized) embedding, payload, and absolute wall-clock expiry
    /// (`u64::MAX` = immortal).
    fn log_insert(
        &self,
        tenant: &str,
        dim: usize,
        id: u64,
        embedding: &[f32],
        entry: &CachedEntry,
        expires_wall_ms: u64,
    );
    /// An explicit removal of entry `id` in `tenant`'s partition `dim`.
    fn log_remove(&self, tenant: &str, dim: usize, id: u64);
    /// A capacity/byte-budget eviction of entry `id` in `tenant`'s
    /// partition `dim`. Journaled so a warm restart does not resurrect
    /// evicted entries from pre-eviction WAL inserts.
    fn log_evict(&self, tenant: &str, dim: usize, id: u64);
    /// A full flush (`/v1/admin` flush).
    fn log_clear(&self);
}

/// A successful lookup.
#[derive(Debug, Clone)]
pub struct CacheHit {
    pub entry: CachedEntry,
    /// Cosine similarity of the matched embedding.
    pub score: f32,
    /// Internal id of the matched entry.
    pub id: u64,
}

/// Tenant- and dimension-partitioned semantic cache. All methods take
/// `&self`; the tenant/partition maps and each partition's ANN index are
/// behind read-mostly `RwLock`s, so concurrent lookups (the batch
/// serving fan-out) share the locks and search in parallel; only
/// inserts, tombstoning, and rebuilds serialize on the write side.
///
/// Methods without a `_for` suffix operate on the default tenant
/// ([`DEFAULT_TENANT`]) — embedded single-tenant use keeps its old API.
pub struct SemanticCache {
    cfg: CacheConfig,
    /// Tenant namespaces, created on first use. Each owns its own
    /// (dim -> partition) map; no partition is ever shared across
    /// tenants.
    tenants: std::sync::RwLock<HashMap<String, Arc<TenantState>>>,
    /// Exact bytes resident across every tenant and partition (each
    /// partition's store mirrors its mutations here).
    bytes: Arc<AtomicU64>,
    policy: Arc<dyn EvictionPolicy>,
    clock: Arc<dyn Clock>,
    /// Mutation observer (WAL); `None` until durability is enabled.
    journal: std::sync::RwLock<Option<Arc<dyn CacheJournal>>>,
    /// Serializes journaled mutations across apply + log so WAL order
    /// always matches in-memory apply order (without it, a remove or
    /// clear racing an insert of the same id could log before the
    /// insert's record, and replay would resurrect the removed entry or
    /// drop an acknowledged one). Uncontended when durability is off —
    /// the non-journal paths never take it.
    journal_gate: std::sync::Mutex<()>,
}

impl SemanticCache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self::with_clock(cfg, Arc::new(crate::store::SystemClock))
    }

    pub fn with_clock(cfg: CacheConfig, clock: Arc<dyn Clock>) -> Self {
        // `validate()` already vets the name on every config path; fall
        // back to LRU rather than panic if an unvalidated config slips
        // through.
        let policy =
            policy_from_name(&cfg.eviction_policy).unwrap_or_else(|_| Arc::new(crate::eviction::Lru));
        Self {
            cfg,
            tenants: std::sync::RwLock::new(HashMap::new()),
            bytes: Arc::new(AtomicU64::new(0)),
            policy,
            clock,
            journal: std::sync::RwLock::new(None),
            journal_gate: std::sync::Mutex::new(()),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The clock this cache (and its partitions' stores) runs on.
    pub fn clock(&self) -> Arc<dyn Clock> {
        self.clock.clone()
    }

    /// Bytes currently resident across all tenants and partitions.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// The global byte budget (0 = unbounded).
    pub fn max_bytes(&self) -> u64 {
        self.cfg.max_bytes
    }

    /// Attach a mutation journal. Called after recovery replay so that
    /// replayed mutations are not logged a second time.
    pub fn set_journal(&self, journal: Arc<dyn CacheJournal>) {
        *self.journal.write().unwrap() = Some(journal);
    }

    fn journal(&self) -> Option<Arc<dyn CacheJournal>> {
        self.journal.read().unwrap().clone()
    }

    /// The per-tenant similarity-threshold override for `tenant`, if the
    /// configuration declares one. Pure config read — no tenant state is
    /// created.
    pub fn tenant_threshold(&self, tenant: &str) -> Option<f32> {
        self.cfg.tenants.get(tenant).and_then(|o| o.similarity_threshold)
    }

    /// The tenant namespace for `name`, created on first use with its
    /// configured quota/threshold overrides. Double-checked
    /// read-then-write: the common case never takes the exclusive lock.
    pub fn tenant(&self, name: &str) -> Arc<TenantState> {
        if let Some(t) = self.tenants.read().unwrap().get(name) {
            return t.clone();
        }
        let mut tenants = self.tenants.write().unwrap();
        tenants
            .entry(name.to_string())
            .or_insert_with(|| {
                let o = self.cfg.tenants.get(name);
                let quota =
                    o.and_then(|o| o.quota_bytes).unwrap_or(self.cfg.tenant_quota_bytes);
                let threshold = o.and_then(|o| o.similarity_threshold);
                Arc::new(TenantState::new(name, quota, threshold))
            })
            .clone()
    }

    /// Every tenant namespace seen so far, sorted by name.
    pub fn tenants(&self) -> Vec<Arc<TenantState>> {
        let mut ts: Vec<Arc<TenantState>> =
            self.tenants.read().unwrap().values().cloned().collect();
        ts.sort_by(|a, b| a.name().cmp(b.name()));
        ts
    }

    /// Point-in-time per-tenant metric blocks, sorted by tenant name
    /// (`/v1/metrics` payload).
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        self.tenants().iter().map(|t| t.stats()).collect()
    }

    /// All populated partitions across every tenant, sorted by
    /// (tenant, dim) — snapshot/recovery iteration order is
    /// deterministic.
    pub fn partitions(&self) -> Vec<Arc<Partition>> {
        let mut parts: Vec<Arc<Partition>> = Vec::new();
        for t in self.tenants.read().unwrap().values() {
            parts.extend(t.partitions.read().unwrap().values().cloned());
        }
        parts.sort_by(|a, b| (a.tenant(), a.dim()).cmp(&(b.tenant(), b.dim())));
        parts
    }

    /// The default tenant's partition for a given embedding size,
    /// created on first use (paper §2.3: "the cache is partitioned based
    /// on the embedding size").
    pub fn partition(&self, dim: usize) -> Arc<Partition> {
        self.partition_for(DEFAULT_TENANT, dim)
    }

    /// `tenant`'s partition for `dim`, created on first use. The new
    /// partition's store charges its byte mutations to the global and
    /// tenant ledgers, and tracks access recency/frequency whenever a
    /// byte budget could require policy-scored eviction.
    pub fn partition_for(&self, tenant: &str, dim: usize) -> Arc<Partition> {
        let t = self.tenant(tenant);
        self.partition_for_state(&t, dim)
    }

    fn partition_for_state(&self, t: &Arc<TenantState>, dim: usize) -> Arc<Partition> {
        if let Some(p) = t.partitions.read().unwrap().get(&dim) {
            return p.clone();
        }
        let mut parts = t.partitions.write().unwrap();
        parts
            .entry(dim)
            .or_insert_with(|| {
                let track = self.cfg.max_bytes > 0 || t.quota_bytes() > 0;
                Arc::new(Partition::new_for_tenant(
                    t.name(),
                    dim,
                    &self.cfg,
                    self.clock.clone(),
                    vec![self.bytes.clone(), t.bytes_ledger()],
                    track,
                ))
            })
            .clone()
    }

    /// The default tenant's partition for `dim` if one has been
    /// populated, without the side effect of creating it.
    pub fn partition_if_exists(&self, dim: usize) -> Option<Arc<Partition>> {
        self.partition_if_exists_for(DEFAULT_TENANT, dim)
    }

    /// `tenant`'s partition for `dim` if populated; never creates tenant
    /// state or partitions.
    pub fn partition_if_exists_for(&self, tenant: &str, dim: usize) -> Option<Arc<Partition>> {
        self.tenants.read().unwrap().get(tenant)?.partitions.read().unwrap().get(&dim).cloned()
    }

    /// Lookup with the configured threshold (default tenant).
    pub fn lookup(&self, embedding: &[f32]) -> Option<CacheHit> {
        self.lookup_with_threshold(embedding, self.cfg.threshold)
    }

    /// Lookup with an explicit threshold (threshold-sweep experiments).
    ///
    /// Empty embeddings and unpopulated partitions miss cleanly (no
    /// partition is allocated as a lookup side effect).
    pub fn lookup_with_threshold(&self, embedding: &[f32], threshold: f32) -> Option<CacheHit> {
        self.lookup_with_opts(embedding, threshold, None)
    }

    /// Lookup with per-request threshold and (optionally) top-k (default
    /// tenant).
    pub fn lookup_with_opts(
        &self,
        embedding: &[f32],
        threshold: f32,
        top_k: Option<usize>,
    ) -> Option<CacheHit> {
        self.lookup_with_opts_for(DEFAULT_TENANT, embedding, threshold, top_k)
    }

    /// Tenant-scoped lookup — the entry point used by the typed serving
    /// API. Only `tenant`'s own partitions are searched; the result also
    /// lands in the tenant's hit/miss counters.
    pub fn lookup_with_opts_for(
        &self,
        tenant: &str,
        embedding: &[f32],
        threshold: f32,
        top_k: Option<usize>,
    ) -> Option<CacheHit> {
        if embedding.is_empty() {
            return None;
        }
        let hit = self
            .partition_if_exists_for(tenant, embedding.len())
            .and_then(|p| p.lookup_k(embedding, threshold, top_k));
        // Count on the tenant that asked, even if it has no state yet —
        // a miss-before-first-insert is still that tenant's miss.
        let t = self.tenant(tenant);
        if hit.is_some() {
            t.record_hit();
        } else {
            t.record_miss();
        }
        hit
    }

    /// Insert a question/response pair under its embedding (default
    /// tenant); returns the new entry's id.
    pub fn try_insert(&self, question: &str, embedding: &[f32], response: &str) -> Result<u64> {
        self.try_insert_entry(
            embedding,
            CachedEntry {
                question: question.to_string(),
                response: response.to_string(),
                cluster: 0,
                latency_ms: 0.0,
            },
        )
    }

    /// Insert an entry under the configured TTL (default tenant).
    pub fn try_insert_entry(&self, embedding: &[f32], entry: CachedEntry) -> Result<u64> {
        self.try_insert_entry_ttl(embedding, entry, None)
    }

    /// Insert an entry with a per-entry TTL override (default tenant).
    pub fn try_insert_entry_ttl(
        &self,
        embedding: &[f32],
        entry: CachedEntry,
        ttl_ms: Option<u64>,
    ) -> Result<u64> {
        self.try_insert_entry_ttl_for(DEFAULT_TENANT, embedding, entry, ttl_ms)
    }

    /// Tenant-scoped insert with a per-entry TTL override (`None` = the
    /// configured default, `Some(0)` = immortal); returns the new id.
    ///
    /// Budget enforcement happens here, inserter-pays: an entry whose
    /// footprint alone exceeds the tenant quota or global budget is
    /// rejected (typed error; the tenant's `quota_rejections` counter is
    /// bumped); otherwise the entry is admitted and the policy evicts
    /// this tenant's lowest-scoring entries until both budgets hold.
    pub fn try_insert_entry_ttl_for(
        &self,
        tenant: &str,
        embedding: &[f32],
        entry: CachedEntry,
        ttl_ms: Option<u64>,
    ) -> Result<u64> {
        if embedding.is_empty() {
            bail!("cannot insert an empty embedding");
        }
        let t = self.tenant(tenant);
        let footprint =
            entry_footprint(entry.question.len(), entry.response.len(), embedding.len());
        let quota = t.quota_bytes();
        if quota > 0 && footprint > quota {
            t.record_quota_rejection();
            bail!(
                "entry footprint {footprint} B exceeds tenant '{tenant}' quota {quota} B"
            );
        }
        if self.cfg.max_bytes > 0 && footprint > self.cfg.max_bytes {
            t.record_quota_rejection();
            bail!(
                "entry footprint {footprint} B exceeds global cache budget {} B",
                self.cfg.max_bytes
            );
        }
        let journal = self.journal();
        // Apply first, then log, with the journal gate held across both
        // (see [`CacheJournal`] ordering) — including the budget
        // evictions this insert triggers, so replay applies them in the
        // same order.
        let _order = journal.as_ref().map(|_| self.journal_gate.lock().unwrap());
        let logged = journal.as_ref().map(|_| entry.clone());
        let p = self.partition_for_state(&t, embedding.len());
        let (id, count_evicted) = p.insert_with_ttl(embedding, entry, ttl_ms);
        t.record_insert();
        t.record_evictions(count_evicted.len() as u64);
        if let Some(j) = &journal {
            let ttl = ttl_ms.unwrap_or(self.cfg.ttl_ms);
            let expires_wall_ms = if ttl == 0 { u64::MAX } else { self.clock.wall_ms() + ttl };
            j.log_insert(
                t.name(),
                embedding.len(),
                id,
                embedding,
                logged.as_ref().expect("cloned alongside journal"),
                expires_wall_ms,
            );
            for ev in &count_evicted {
                j.log_evict(t.name(), embedding.len(), *ev);
            }
        }
        self.enforce_budgets(&t, journal.as_ref());
        Ok(id)
    }

    /// Evict `t`'s lowest-scoring entries until its quota and the global
    /// budget both hold. Only the inserting tenant's partitions are
    /// scanned — quota pressure (and even global pressure this tenant
    /// caused) can never evict another tenant's entries.
    fn enforce_budgets(&self, t: &Arc<TenantState>, journal: Option<&Arc<dyn CacheJournal>>) {
        let quota = t.quota_bytes();
        let max = self.cfg.max_bytes;
        if quota == 0 && max == 0 {
            return;
        }
        loop {
            let over_quota = quota > 0 && t.bytes() > quota;
            let over_global = max > 0 && self.bytes() > max;
            if !over_quota && !over_global {
                break;
            }
            let parts: Vec<Arc<Partition>> =
                t.partitions.read().unwrap().values().cloned().collect();
            let mut best: Option<(Arc<Partition>, PartitionVictim)> = None;
            for p in parts {
                if let Some(v) = p.victim(self.policy.as_ref()) {
                    let better = match &best {
                        None => true,
                        Some((_, b)) => {
                            v.score < b.score || (v.score == b.score && v.seq < b.seq)
                        }
                    };
                    if better {
                        best = Some((p, v));
                    }
                }
            }
            let Some((p, v)) = best else {
                break; // nothing left to evict in this tenant
            };
            if p.evict_id(v.id).is_some() {
                t.record_evictions(1);
                if let Some(j) = journal {
                    j.log_evict(t.name(), p.dim(), v.id);
                }
            }
            // A raced eviction (None) just rescans on the next turn.
        }
    }

    /// Remove one entry in the default tenant by partition dim and id.
    pub fn remove_entry(&self, dim: usize, id: u64) -> bool {
        self.remove_entry_for(DEFAULT_TENANT, dim, id)
    }

    /// Remove one entry by tenant, partition dim, and id (store, index,
    /// and embedding map together). Returns whether a live entry was
    /// removed.
    pub fn remove_entry_for(&self, tenant: &str, dim: usize, id: u64) -> bool {
        match self.journal() {
            None => {
                self.partition_if_exists_for(tenant, dim).map_or(false, |p| p.remove_id(id))
            }
            Some(journal) => {
                let _order = self.journal_gate.lock().unwrap();
                let Some(p) = self.partition_if_exists_for(tenant, dim) else {
                    return false;
                };
                let removed = p.remove_id(id);
                if removed {
                    journal.log_remove(tenant, dim, id);
                }
                removed
            }
        }
    }

    /// Pre-v1 insert with the `0 = rejected` sentinel.
    #[deprecated(since = "0.2.0", note = "use try_insert, which reports rejection as an error")]
    pub fn insert(&self, question: &str, embedding: &[f32], response: &str) -> u64 {
        self.try_insert(question, embedding, response).unwrap_or(0)
    }

    /// Pre-v1 entry insert with the `0 = rejected` sentinel (never a
    /// real id — ids start at 1).
    #[deprecated(
        since = "0.2.0",
        note = "use try_insert_entry, which reports rejection as an error"
    )]
    pub fn insert_entry(&self, embedding: &[f32], entry: CachedEntry) -> u64 {
        self.try_insert_entry(embedding, entry).unwrap_or(0)
    }

    /// Drop every entry and partition across every tenant. Returns the
    /// number of live entries removed (the `/v1/admin` flush operation).
    /// Tenant namespaces (and their counters) survive the flush; only
    /// cached data is dropped, and every byte ledger resets to zero.
    pub fn clear(&self) -> usize {
        let _order = self.journal().map(|_| self.journal_gate.lock().unwrap());
        let removed = {
            let tenants = self.tenants.read().unwrap();
            let mut removed = 0;
            for t in tenants.values() {
                let mut parts = t.partitions.write().unwrap();
                removed += parts.values().map(|p| p.len()).sum::<usize>();
                parts.clear();
                t.reset_bytes();
            }
            removed
        };
        self.bytes.store(0, Ordering::Relaxed);
        if let Some(journal) = self.journal() {
            journal.log_clear();
        }
        removed
    }

    /// Total live entries across every tenant and partition.
    pub fn len(&self) -> usize {
        self.partitions().iter().map(|p| p.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Housekeeping pass: sweep expired entries and rebuild indexes whose
    /// garbage ratio exceeds the configured bound, across every tenant.
    /// Returns (expired, rebuilt-partition count). Driven by the
    /// coordinator's timer.
    pub fn housekeep(&self) -> (usize, usize) {
        let mut expired = 0;
        let mut rebuilt = 0;
        for p in self.partitions() {
            expired += p.sweep_expired();
            if p.garbage_ratio() > self.cfg.rebuild_garbage_ratio && p.rebuild() {
                rebuilt += 1;
            }
        }
        (expired, rebuilt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ManualClock;

    fn unit(dim: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = 1.0;
        v
    }

    /// A vector leaning toward axis `hot` with a controlled cosine.
    fn near(dim: usize, hot: usize, cos: f32) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[hot] = cos;
        v[(hot + 1) % dim] = (1.0 - cos * cos).sqrt();
        v
    }

    fn entry(q: &str, latency_ms: f64) -> CachedEntry {
        CachedEntry { question: q.into(), response: q.into(), cluster: 0, latency_ms }
    }

    #[test]
    fn miss_insert_hit_workflow() {
        let cache = SemanticCache::new(CacheConfig::default());
        let e = unit(16, 3);
        assert!(cache.lookup(&e).is_none());
        cache.try_insert("q", &e, "r").unwrap();
        let hit = cache.lookup(&e).expect("exact match hits");
        assert_eq!(hit.entry.response, "r");
        assert!(hit.score > 0.999);
    }

    #[test]
    fn threshold_gates_hits() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("q", &unit(16, 0), "r").unwrap();
        // cos 0.9 passes the 0.8 gate; cos 0.7 does not.
        assert!(cache.lookup(&near(16, 0, 0.9)).is_some());
        assert!(cache.lookup(&near(16, 0, 0.7)).is_none());
        // but a lenient explicit threshold accepts it.
        assert!(cache.lookup_with_threshold(&near(16, 0, 0.7), 0.6).is_some());
    }

    #[test]
    fn partitions_by_dim_are_independent() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("a", &unit(16, 0), "r16").unwrap();
        cache.try_insert("b", &unit(32, 0), "r32").unwrap();
        assert_eq!(cache.len(), 2);
        let hit = cache.lookup(&unit(32, 0)).unwrap();
        assert_eq!(hit.entry.response, "r32");
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "r16");
    }

    #[test]
    fn ttl_expires_entries() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 1_000, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        let e = unit(8, 2);
        cache.try_insert("q", &e, "r").unwrap();
        assert!(cache.lookup(&e).is_some());
        clock.advance(1_500);
        assert!(cache.lookup(&e).is_none(), "expired entry must not hit");
        // Sweep reclaims both store and (after rebuild check) index slots.
        let (expired, _) = cache.housekeep();
        // The lazy lookup above already dropped it from the store; sweep
        // finds zero or counts it once depending on timing — both fine,
        // but len() must be 0 either way.
        let _ = expired;
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn empty_embedding_and_unpopulated_partition_miss_cleanly() {
        let cache = SemanticCache::new(CacheConfig::default());
        // Empty embedding: lookup misses, insert is a typed rejection —
        // and the deprecated sentinel shim still reports it as 0.
        assert!(cache.lookup(&[]).is_none());
        assert!(cache.try_insert("q", &[], "r").is_err());
        #[allow(deprecated)]
        {
            assert_eq!(cache.insert("q", &[], "r"), 0);
        }
        assert_eq!(cache.len(), 0);
        // Lookup against a dimension that was never populated must miss
        // without allocating a partition as a side effect.
        assert!(cache.lookup(&unit(24, 0)).is_none());
        assert!(cache.partition_if_exists(24).is_none());
        // A real insert then behaves normally.
        cache.try_insert("q", &unit(24, 0), "r").unwrap();
        assert!(cache.partition_if_exists(24).is_some());
        assert!(cache.lookup(&unit(24, 0)).is_some());
    }

    #[test]
    fn best_of_multiple_candidates_wins() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("far", &near(16, 0, 0.85), "far-r").unwrap();
        cache.try_insert("near", &unit(16, 0), "near-r").unwrap();
        let hit = cache.lookup(&unit(16, 0)).unwrap();
        assert_eq!(hit.entry.response, "near-r");
    }

    #[test]
    fn housekeep_rebuilds_garbage_heavy_partition() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 100, rebuild_garbage_ratio: 0.2, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        for i in 0..50 {
            cache.try_insert(&format!("q{i}"), &near(16, i % 16, 0.99), "r").unwrap();
        }
        clock.advance(200);
        let (expired, rebuilt) = cache.housekeep();
        assert_eq!(expired, 50);
        assert_eq!(rebuilt, 1, "all entries dead -> garbage ratio 1.0 -> rebuild");
        assert_eq!(cache.len(), 0);
        // Cache still works after rebuild.
        cache.try_insert("fresh", &unit(16, 5), "fr").unwrap();
        clock.advance(50);
        assert!(cache.lookup(&unit(16, 5)).is_some());
    }

    #[test]
    fn builder_accepts_valid_and_rejects_invalid() {
        let cfg = CacheConfig::builder()
            .threshold(0.85)
            .ttl_ms(1_000)
            .capacity(100)
            .top_k(3)
            .index(IndexKind::Flat)
            .rebuild_garbage_ratio(0.5)
            .store_shards(4)
            .max_bytes(1 << 20)
            .eviction_policy("cost")
            .tenant_quota_bytes(1 << 16)
            .tenant("alice", TenantOverrides { quota_bytes: Some(1 << 18), similarity_threshold: Some(0.9) })
            .build()
            .unwrap();
        assert_eq!(cfg.threshold, 0.85);
        assert_eq!(cfg.top_k, 3);
        assert_eq!(cfg.index, IndexKind::Flat);
        assert_eq!(cfg.max_bytes, 1 << 20);
        assert_eq!(cfg.eviction_policy, "cost");
        assert_eq!(cfg.tenants["alice"].quota_bytes, Some(1 << 18));

        assert!(CacheConfig::builder().threshold(f32::NAN).build().is_err(), "NaN threshold");
        assert!(CacheConfig::builder().threshold(1.5).build().is_err(), "threshold > 1");
        assert!(CacheConfig::builder().threshold(-0.1).build().is_err(), "threshold < 0");
        assert!(CacheConfig::builder().top_k(0).build().is_err(), "top_k == 0");
        assert!(CacheConfig::builder().store_shards(0).build().is_err(), "store_shards == 0");
        assert!(
            CacheConfig::builder().rebuild_garbage_ratio(f64::NAN).build().is_err(),
            "NaN garbage ratio"
        );
        assert!(
            CacheConfig::builder().rebuild_garbage_ratio(2.0).build().is_err(),
            "garbage ratio > 1"
        );
        assert!(
            CacheConfig::builder().eviction_policy("random").build().is_err(),
            "unknown eviction policy"
        );
        assert!(
            CacheConfig::builder()
                .tenant("bob", TenantOverrides { quota_bytes: None, similarity_threshold: Some(1.5) })
                .build()
                .is_err(),
            "tenant threshold out of range"
        );
    }

    #[test]
    fn clear_drops_all_partitions() {
        let cache = SemanticCache::new(CacheConfig::default());
        cache.try_insert("a", &unit(16, 0), "r16").unwrap();
        cache.try_insert("b", &unit(32, 0), "r32").unwrap();
        assert_eq!(cache.clear(), 2);
        assert_eq!(cache.len(), 0);
        assert!(cache.lookup(&unit(16, 0)).is_none());
        // The cache keeps serving after a flush.
        cache.try_insert("c", &unit(16, 1), "again").unwrap();
        assert!(cache.lookup(&unit(16, 1)).is_some());
    }

    #[test]
    fn per_entry_ttl_overrides_config_default() {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: 10_000, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock.clone());
        let short = unit(8, 0);
        let default = unit(8, 2);
        let immortal = unit(8, 4);
        cache.try_insert_entry_ttl(&short, entry("short", 0.0), Some(500)).unwrap();
        cache.try_insert_entry_ttl(&default, entry("default", 0.0), None).unwrap();
        cache.try_insert_entry_ttl(&immortal, entry("immortal", 0.0), Some(0)).unwrap();
        clock.advance(1_000);
        assert!(cache.lookup(&short).is_none(), "short-TTL entry must expire first");
        assert!(cache.lookup(&default).is_some());
        clock.advance(20_000);
        assert!(cache.lookup(&default).is_none(), "config default TTL still applies");
        assert!(cache.lookup(&immortal).is_some(), "ttl 0 pins the entry");
    }

    #[test]
    fn per_request_top_k_overrides_config() {
        // Config top_k = 1 and a per-request override of 5 must agree on
        // the best-scoring hit (the override widens the candidate set,
        // never changes the winner).
        let cfg = CacheConfig { index: IndexKind::Flat, top_k: 1, ..Default::default() };
        let cache = SemanticCache::new(cfg);
        cache.try_insert("best", &unit(16, 0), "best-r").unwrap();
        cache.try_insert("other", &near(16, 0, 0.9), "other-r").unwrap();
        // Default (config) top_k = 1: best match wins.
        let hit = cache.lookup_with_opts(&unit(16, 0), 0.8, None).unwrap();
        assert_eq!(hit.entry.response, "best-r");
        // Per-request top_k = 5 must behave identically for the best hit.
        let hit = cache.lookup_with_opts(&unit(16, 0), 0.8, Some(5)).unwrap();
        assert_eq!(hit.entry.response, "best-r");
    }

    #[test]
    fn tenants_are_isolated_namespaces() {
        let cache = SemanticCache::new(CacheConfig::default());
        let e = unit(16, 0);
        cache
            .try_insert_entry_ttl_for("alice", &e, entry("alice-q", 0.0), None)
            .unwrap();
        // Bob searching the identical embedding must miss: lookups never
        // cross tenant boundaries.
        assert!(cache.lookup_with_opts_for("bob", &e, 0.8, None).is_none());
        assert!(cache.lookup_with_opts_for("alice", &e, 0.8, None).is_some());
        // The default tenant is just another namespace.
        assert!(cache.lookup(&e).is_none());
        let stats: std::collections::HashMap<String, _> =
            cache.tenant_stats().into_iter().map(|s| (s.name.clone(), s)).collect();
        assert_eq!(stats["alice"].hits, 1);
        assert_eq!(stats["alice"].inserts, 1);
        assert_eq!(stats["bob"].misses, 1);
        assert_eq!(stats["bob"].entries, 0);
    }

    #[test]
    fn global_byte_budget_evicts_inserter_lru_first(){
        let clock = Arc::new(ManualClock::new(0));
        // Budget fits ~3 of these entries (q/r 4 bytes each, dim 8).
        let one = entry_footprint(4, 4, 8);
        let cfg = CacheConfig { max_bytes: 3 * one, ..Default::default() };
        let cache = SemanticCache::with_clock(cfg, clock);
        let ids: Vec<u64> = (0..4)
            .map(|i| {
                cache
                    .try_insert_entry_ttl(&unit(8, i), entry("aaaa", 1.0), None)
                    .unwrap()
            })
            .collect();
        // 4 inserts under a 3-entry budget: exactly one LRU eviction,
        // and the survivor set is the 3 youngest.
        assert_eq!(cache.len(), 3);
        assert!(cache.bytes() <= 3 * one, "bytes {} > budget {}", cache.bytes(), 3 * one);
        assert!(cache.lookup(&unit(8, 0)).is_none(), "oldest entry evicted");
        for i in 1..4 {
            assert!(cache.lookup(&unit(8, i)).is_some(), "young entry {i} survived");
        }
        let _ = ids;
        let stats = &cache.tenant_stats()[0];
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.bytes, 3 * one);
    }

    #[test]
    fn tenant_quota_never_evicts_other_tenants() {
        let one = entry_footprint(4, 4, 8);
        let cfg = CacheConfig { tenant_quota_bytes: 2 * one, ..Default::default() };
        let cache = SemanticCache::new(cfg);
        // Cold tenant parks two entries well within its own quota.
        for i in 0..2 {
            cache
                .try_insert_entry_ttl_for("cold", &unit(8, i), entry("aaaa", 0.0), None)
                .unwrap();
        }
        // Hot tenant floods 6 entries through a 2-entry quota.
        for i in 0..6 {
            cache
                .try_insert_entry_ttl_for("hot", &unit(8, i), entry("bbbb", 0.0), None)
                .unwrap();
        }
        let stats: std::collections::HashMap<String, _> =
            cache.tenant_stats().into_iter().map(|s| (s.name.clone(), s)).collect();
        assert_eq!(stats["hot"].evictions, 4, "hot tenant paid for its own pressure");
        assert_eq!(stats["cold"].evictions, 0, "cold tenant untouched");
        assert!(stats["hot"].bytes <= 2 * one);
        assert_eq!(stats["cold"].entries, 2);
        for i in 0..2 {
            assert!(
                cache.lookup_with_opts_for("cold", &unit(8, i), 0.8, None).is_some(),
                "cold entry {i} must survive the hot flood"
            );
        }
    }

    #[test]
    fn oversized_entry_is_a_typed_rejection() {
        let cfg = CacheConfig { tenant_quota_bytes: 64, ..Default::default() };
        let cache = SemanticCache::new(cfg);
        let err = cache
            .try_insert_entry_ttl_for("t", &unit(8, 0), entry("way too big", 0.0), None)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("quota"), "reason names the quota: {msg}");
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.tenant_stats()[0].quota_rejections, 1);
    }

    #[test]
    fn cost_aware_policy_keeps_expensive_entries() {
        let one = entry_footprint(4, 4, 8);
        let cfg = CacheConfig {
            max_bytes: 2 * one,
            eviction_policy: "cost".to_string(),
            ..Default::default()
        };
        let cache = SemanticCache::new(cfg);
        // An entry that saves 5s of LLM latency per hit vs one that
        // saves 1ms: under byte pressure the cheap one goes first even
        // though the pricey one is older (LRU would pick it).
        cache.try_insert_entry_ttl(&unit(8, 0), entry("aaaa", 5_000.0), None).unwrap();
        cache.try_insert_entry_ttl(&unit(8, 1), entry("bbbb", 1.0), None).unwrap();
        cache.try_insert_entry_ttl(&unit(8, 2), entry("cccc", 1_000.0), None).unwrap();
        assert!(cache.lookup(&unit(8, 0)).is_some(), "high-value entry must survive");
        assert!(cache.lookup(&unit(8, 1)).is_none(), "low-value entry sacrificed");
        assert!(cache.lookup(&unit(8, 2)).is_some());
    }

    #[test]
    fn per_tenant_threshold_override_is_exposed() {
        let cfg = CacheConfig::builder()
            .tenant(
                "strict",
                TenantOverrides { quota_bytes: None, similarity_threshold: Some(0.95) },
            )
            .build()
            .unwrap();
        let cache = SemanticCache::new(cfg);
        assert_eq!(cache.tenant_threshold("strict"), Some(0.95));
        assert_eq!(cache.tenant_threshold("lenient"), None);
        // And the tenant state carries it for serving-layer resolution.
        assert_eq!(cache.tenant("strict").threshold(), Some(0.95));
    }
}
