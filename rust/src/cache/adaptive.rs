//! Dynamic threshold adjustment — the paper's §2.10 extension,
//! implemented as a per-category additive-increase / additive-decrease
//! controller driven by judge feedback:
//!
//! * a **negative** hit (judge says the cached answer was wrong) means
//!   the gate let a bad match through → raise the threshold;
//! * a long run of positive hits means the gate may be too strict
//!   (cache hits being left on the table) → lower it slowly.
//!
//! The asymmetric step sizes (fast up, slow down) keep accuracy pinned
//! near the target while recovering hit rate over time; the threshold is
//! clamped to a sane band around the paper's 0.8.

/// AIAD threshold controller.
#[derive(Debug, Clone)]
pub struct AdaptiveThreshold {
    value: f32,
    /// Raise by this much on a negative hit.
    up_step: f32,
    /// Lower by this much per `down_every` consecutive positives.
    down_step: f32,
    down_every: u32,
    streak: u32,
    min: f32,
    max: f32,
}

impl AdaptiveThreshold {
    pub fn new(initial: f32) -> Self {
        Self {
            value: initial,
            up_step: 0.01,
            down_step: 0.002,
            down_every: 20,
            streak: 0,
            min: 0.70,
            max: 0.95,
        }
    }

    pub fn with_band(initial: f32, min: f32, max: f32) -> Self {
        let mut a = Self::new(initial);
        a.min = min;
        a.max = max;
        a.value = initial.clamp(min, max);
        a
    }

    /// Current threshold.
    pub fn get(&self) -> f32 {
        self.value
    }

    /// Feed one judged hit.
    pub fn observe(&mut self, positive: bool) {
        if positive {
            self.streak += 1;
            if self.streak >= self.down_every {
                self.streak = 0;
                self.value = (self.value - self.down_step).max(self.min);
            }
        } else {
            self.streak = 0;
            self.value = (self.value + self.up_step).min(self.max);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negatives_raise_threshold() {
        let mut a = AdaptiveThreshold::new(0.8);
        for _ in 0..5 {
            a.observe(false);
        }
        assert!((a.get() - 0.85).abs() < 1e-6);
    }

    #[test]
    fn positives_lower_slowly() {
        let mut a = AdaptiveThreshold::new(0.8);
        for _ in 0..40 {
            a.observe(true);
        }
        assert!((a.get() - (0.8 - 2.0 * 0.002)).abs() < 1e-6);
    }

    #[test]
    fn clamped_to_band() {
        let mut a = AdaptiveThreshold::with_band(0.8, 0.75, 0.85);
        for _ in 0..100 {
            a.observe(false);
        }
        assert_eq!(a.get(), 0.85);
        for _ in 0..100_000 {
            a.observe(true);
        }
        assert_eq!(a.get(), 0.75);
    }

    #[test]
    fn negative_resets_streak() {
        let mut a = AdaptiveThreshold::new(0.8);
        for _ in 0..19 {
            a.observe(true);
        }
        a.observe(false); // resets streak and bumps up
        for _ in 0..19 {
            a.observe(true);
        }
        // Never reached 20-streak after the negative: no down-steps.
        assert!((a.get() - 0.81).abs() < 1e-6);
    }
}
