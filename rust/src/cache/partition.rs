//! One embedding-size partition: ANN index + TTL'd entry store.
//!
//! The index and the store can disagree transiently: the index may hold
//! ids whose store entry has expired (TTL) or been evicted. Lookups
//! treat such ids as dead — they are skipped (and the index tombstoned)
//! — and the housekeeping rebuild reclaims the slots. This mirrors the
//! paper's Redis-TTL + ANN-index split, where Redis expiry is the source
//! of truth (§2.7).
//!
//! Since the tenancy refactor a partition belongs to exactly one tenant
//! ([`Partition::tenant`]); the cache keys partitions on (tenant, dim),
//! which is what makes cross-tenant lookups structurally impossible.
//! Every insert charges its [`crate::eviction::entry_footprint`] to the
//! store's byte ledger (threaded through to the tenant and global
//! ledgers), and the byte-budget enforcement loop in
//! [`super::SemanticCache`] uses [`Partition::victim`] /
//! [`Partition::evict_id`] to pick and reclaim entries.
//!
//! Concurrency: the ANN index sits behind a read-mostly `RwLock`, so any
//! number of batch workers can search one partition in parallel; only
//! inserts, tombstoning of dead ids, and rebuilds take the write lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::eviction::{entry_footprint, EvictionPolicy};
use crate::index::{FlatIndex, HnswIndex, VectorIndex};
use crate::store::{Clock, KvStore, StoreConfig};
use crate::tenancy::DEFAULT_TENANT;

use super::{CacheConfig, CacheHit, CachedEntry, IndexKind};

pub struct Partition {
    tenant: String,
    dim: usize,
    /// Read-mostly: `search` under the shared lock, mutation under the
    /// exclusive lock.
    index: RwLock<Box<dyn VectorIndex>>,
    store: KvStore<CachedEntry>,
    next_id: AtomicU64,
    /// Embeddings of live entries, kept for rebuilds (id -> embedding).
    embeddings: Mutex<std::collections::HashMap<u64, Vec<f32>>>,
    top_k: usize,
    default_ttl_ms: u64,
    /// Whether indexes built for this partition use the int8 quantized
    /// candidate scan (`quantized_scan` config key). Recorded so
    /// rebuilds and recovered-graph installs reproduce the same kernel
    /// choice as the original construction.
    quantized: bool,
    clock: Arc<dyn Clock>,
}

fn key(id: u64) -> String {
    format!("e{id:016x}")
}

/// One entry as captured by [`Partition::dump`]: everything needed to
/// reconstruct it in a fresh process. Expiry is wall-clock absolute
/// (`u64::MAX` = immortal) so it survives the restart of the process'
/// monotonic epoch.
#[derive(Debug, Clone)]
pub struct EntryDump {
    pub id: u64,
    pub expires_wall_ms: u64,
    pub entry: CachedEntry,
    pub embedding: Vec<f32>,
}

/// Point-in-time capture of one partition (snapshot payload).
pub struct PartitionDump {
    /// Owning tenant namespace.
    pub tenant: String,
    pub dim: usize,
    pub next_id: u64,
    /// Live entries, sorted by id (deterministic bytes for a given state).
    pub entries: Vec<EntryDump>,
    /// Serialized ANN graph, when the index kind supports it (HNSW).
    pub graph: Option<Vec<u8>>,
}

/// A byte-budget eviction candidate ([`Partition::victim`]).
#[derive(Debug, Clone)]
pub struct PartitionVictim {
    pub id: u64,
    pub score: f64,
    pub seq: u64,
    pub bytes: u64,
}

impl Partition {
    /// Default-tenant partition with no shared byte ledgers (tests and
    /// embedded single-tenant use).
    pub fn new(dim: usize, cfg: &CacheConfig, clock: Arc<dyn Clock>) -> Self {
        Self::new_for_tenant(DEFAULT_TENANT, dim, cfg, clock, Vec::new(), false)
    }

    /// A partition owned by `tenant`. `ledgers` are the byte counters
    /// (global + tenant) every weighted store mutation updates;
    /// `track_access` keeps recency/frequency metadata on reads (needed
    /// whenever a byte budget can trigger policy-scored eviction).
    pub fn new_for_tenant(
        tenant: &str,
        dim: usize,
        cfg: &CacheConfig,
        clock: Arc<dyn Clock>,
        ledgers: Vec<Arc<AtomicU64>>,
        track_access: bool,
    ) -> Self {
        let index: Box<dyn VectorIndex> = match cfg.index {
            IndexKind::Hnsw => {
                Box::new(HnswIndex::with_quantized(dim, cfg.hnsw.clone(), cfg.quantized_scan))
            }
            IndexKind::Flat => Box::new(FlatIndex::with_quantized(dim, cfg.quantized_scan)),
        };
        let store = KvStore::with_clock(
            StoreConfig {
                shards: cfg.store_shards,
                capacity: cfg.capacity,
                default_ttl_ms: cfg.ttl_ms,
                track_access,
                ledgers,
            },
            clock.clone(),
        );
        Self {
            tenant: tenant.to_string(),
            dim,
            index: RwLock::new(index),
            store,
            next_id: AtomicU64::new(1),
            embeddings: Mutex::new(std::collections::HashMap::new()),
            top_k: cfg.top_k.max(1),
            default_ttl_ms: cfg.ttl_ms,
            quantized: cfg.quantized_scan,
            clock,
        }
    }

    /// Whether this partition's indexes run the quantized candidate
    /// scan (recovery re-applies this to loaded graphs).
    pub fn quantized(&self) -> bool {
        self.quantized
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The tenant namespace this partition belongs to.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Bytes resident in this partition's store.
    pub fn bytes(&self) -> u64 {
        self.store.bytes()
    }

    pub fn lookup(&self, embedding: &[f32], threshold: f32) -> Option<CacheHit> {
        self.lookup_k(embedding, threshold, None)
    }

    /// Lookup with a per-request candidate-set width (`None` = the
    /// configured `top_k`).
    pub fn lookup_k(
        &self,
        embedding: &[f32],
        threshold: f32,
        top_k: Option<usize>,
    ) -> Option<CacheHit> {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        let k = top_k.unwrap_or(self.top_k).max(1);
        let neighbors = {
            // Shared lock: concurrent lookups search in parallel.
            let index = self.index.read().unwrap();
            index.search(embedding, k)
        };
        for n in neighbors {
            if n.score < threshold {
                break; // results are sorted; nothing below can pass
            }
            match self.store.get(&key(n.id)) {
                Some(entry) => {
                    return Some(CacheHit { entry, score: n.score, id: n.id });
                }
                None => {
                    // Expired/evicted in the store: tombstone the index id
                    // so future searches skip it; rebuild reclaims later.
                    self.index.write().unwrap().remove(n.id);
                    self.embeddings.lock().unwrap().remove(&n.id);
                }
            }
        }
        None
    }

    pub fn insert(&self, embedding: &[f32], entry: CachedEntry) -> u64 {
        self.insert_with_ttl(embedding, entry, None).0
    }

    /// Insert with a per-entry TTL override (`None` = store default,
    /// `Some(0)` = immortal). Returns the new id plus the ids evicted by
    /// the legacy count capacity (already tombstoned here; the caller
    /// journals them).
    pub fn insert_with_ttl(
        &self,
        embedding: &[f32],
        entry: CachedEntry,
        ttl_ms: Option<u64>,
    ) -> (u64, Vec<u64>) {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let bytes = entry_footprint(entry.question.len(), entry.response.len(), self.dim);
        let cost = entry.latency_ms;
        let ttl = ttl_ms.unwrap_or(self.default_ttl_ms);
        let evicted_keys = self.store.set_ttl_weighted(&key(id), entry, ttl, bytes, cost);
        self.embeddings.lock().unwrap().insert(id, embedding.to_vec());
        self.index.write().unwrap().insert(id, embedding);
        let evicted = self.tombstone_keys(&evicted_keys);
        (id, evicted)
    }

    /// Live entry count (store is the source of truth).
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// Next id this partition would assign (persisted so recovered
    /// processes never reuse an id).
    pub fn next_id(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Ensure future ids start at `floor` or later.
    pub fn bump_next_id(&self, floor: u64) {
        self.next_id.fetch_max(floor, Ordering::Relaxed);
    }

    /// Tombstone index nodes + embeddings for store keys that were
    /// removed underneath us (count-capacity eviction); returns the ids.
    fn tombstone_keys(&self, keys: &[String]) -> Vec<u64> {
        if keys.is_empty() {
            return Vec::new();
        }
        let mut ids = Vec::with_capacity(keys.len());
        let mut index = self.index.write().unwrap();
        let mut embeddings = self.embeddings.lock().unwrap();
        for k in keys {
            if let Ok(id) = u64::from_str_radix(&k[1..], 16) {
                index.remove(id);
                embeddings.remove(&id);
                ids.push(id);
            }
        }
        ids
    }

    /// Drop expired entries from the store *and* tombstone their index
    /// nodes + embeddings in the same pass; returns the count.
    ///
    /// This is the one sweep path: sweeping only the store (the old
    /// behaviour) left the partition's index nodes live, so expired
    /// entries kept steering searches and `garbage_ratio()` under-counted
    /// until a lookup happened to trip over each dead id.
    pub fn sweep_expired(&self) -> usize {
        let keys = self.store.sweep_expired_keys();
        if keys.is_empty() {
            return 0;
        }
        self.tombstone_keys(&keys);
        keys.len()
    }

    /// The lowest-scoring resident entry under `policy` — the byte
    /// budget's next victim in this partition (expired residents score
    /// negative infinity). O(n) in resident entries.
    pub fn victim(&self, policy: &dyn EvictionPolicy) -> Option<PartitionVictim> {
        let v = self.store.victim(policy)?;
        let id = u64::from_str_radix(&v.key[1..], 16).ok()?;
        Some(PartitionVictim { id, score: v.score, seq: v.seq, bytes: v.bytes })
    }

    /// Byte-budget eviction of one entry: remove it from the store
    /// (releasing its footprint from every ledger) and tombstone its
    /// index node + embedding. Returns the freed bytes if it was
    /// resident.
    pub fn evict_id(&self, id: u64) -> Option<u64> {
        let freed = self.store.evict(&key(id))?;
        self.index.write().unwrap().remove(id);
        self.embeddings.lock().unwrap().remove(&id);
        Some(freed)
    }

    /// Garbage fraction of the index: tombstoned slots plus entries dead
    /// in the store but still live in the index (0 when empty).
    pub fn garbage_ratio(&self) -> f64 {
        let index = self.index.read().unwrap();
        let live = self.store.len();
        // slots() counts tombstoned HNSW nodes too, so graph garbage is
        // visible even after the store and index agree on live entries.
        let slots = index.slots().max(live);
        if slots == 0 {
            return 0.0;
        }
        1.0 - live as f64 / slots as f64
    }

    /// Rebuild the index from live store entries; true if rebuilt.
    pub fn rebuild(&self) -> bool {
        // Collect live ids from the store.
        let mut live: Vec<(u64, Vec<f32>)> = Vec::new();
        {
            let embeddings = self.embeddings.lock().unwrap();
            self.store.for_each(|k, _| {
                if let Ok(id) = u64::from_str_radix(&k[1..], 16) {
                    if let Some(e) = embeddings.get(&id) {
                        live.push((id, e.clone()));
                    }
                }
            });
        }
        let mut index = self.index.write().unwrap();
        if index.len() == 0 && live.is_empty() {
            return false;
        }
        // Recreate the same concrete index kind, populated with live rows.
        let mut fresh: Box<dyn VectorIndex> = if index.is_hnsw() {
            Box::new(HnswIndex::with_quantized(
                self.dim,
                index.hnsw_config().expect("hnsw").clone(),
                self.quantized,
            ))
        } else {
            Box::new(FlatIndex::with_quantized(self.dim, self.quantized))
        };
        for (id, e) in &live {
            fresh.insert(*id, e);
        }
        *index = fresh;
        // Drop embeddings of dead ids.
        let live_ids: std::collections::HashSet<u64> = live.iter().map(|(id, _)| *id).collect();
        self.embeddings.lock().unwrap().retain(|id, _| live_ids.contains(id));
        true
    }

    /// Capture this partition for a snapshot: live entries (wall-clock
    /// expiry), their embeddings, the id allocator, and the serialized
    /// ANN graph where the index kind supports it.
    pub fn dump(&self) -> PartitionDump {
        let now_mono = self.clock.now_ms();
        let wall_now = self.clock.wall_ms();
        let mut entries = Vec::new();
        {
            let embeddings = self.embeddings.lock().unwrap();
            self.store.for_each_with_expiry(|k, v, exp| {
                if let Ok(id) = u64::from_str_radix(&k[1..], 16) {
                    if let Some(e) = embeddings.get(&id) {
                        let expires_wall_ms = if exp == u64::MAX {
                            u64::MAX
                        } else {
                            wall_now + exp.saturating_sub(now_mono)
                        };
                        entries.push(EntryDump {
                            id,
                            expires_wall_ms,
                            entry: v.clone(),
                            embedding: e.clone(),
                        });
                    }
                }
            });
        }
        entries.sort_by_key(|e| e.id);
        let graph = self.index.read().unwrap().dump_graph();
        PartitionDump {
            tenant: self.tenant.clone(),
            dim: self.dim,
            next_id: self.next_id(),
            entries,
            graph,
        }
    }

    /// Re-apply one persisted entry during recovery. Entries whose wall
    /// expiry already passed (they died during downtime) are not
    /// restored; any matching node in an installed graph is tombstoned
    /// instead, to be reclaimed by the next snapshot's compaction.
    /// Returns whether the entry was restored live.
    pub fn restore_entry(
        &self,
        id: u64,
        embedding: &[f32],
        entry: CachedEntry,
        expires_wall_ms: u64,
    ) -> bool {
        if embedding.len() != self.dim {
            return false; // malformed record: never panic on recovery
        }
        self.bump_next_id(id + 1);
        let wall_now = self.clock.wall_ms();
        if expires_wall_ms != u64::MAX && expires_wall_ms <= wall_now {
            self.index.write().unwrap().remove(id);
            self.embeddings.lock().unwrap().remove(&id);
            return false;
        }
        let ttl = if expires_wall_ms == u64::MAX { 0 } else { expires_wall_ms - wall_now };
        let bytes = entry_footprint(entry.question.len(), entry.response.len(), self.dim);
        let cost = entry.latency_ms;
        // Weighted restore: recovered entries re-charge the byte ledgers
        // exactly like live inserts did.
        self.store.set_ttl_weighted(&key(id), entry, ttl, bytes, cost);
        self.embeddings.lock().unwrap().insert(id, embedding.to_vec());
        // For graph-loaded ids this is an in-place vector overwrite (the
        // normalization is deterministic, so the stored bits are
        // unchanged); for WAL-suffix ids it is a real graph insert,
        // replayed in original insert order against the snapshotted
        // level-sampler state — the rebuilt graph matches the live one.
        self.index.write().unwrap().insert(id, embedding);
        true
    }

    /// Remove an entry by id across store, index, and embedding map.
    /// Returns whether the store held it live.
    pub fn remove_id(&self, id: u64) -> bool {
        let was_live = self.store.remove(&key(id));
        self.index.write().unwrap().remove(id);
        self.embeddings.lock().unwrap().remove(&id);
        was_live
    }

    /// Replace the ANN index with a recovered one (must match this
    /// partition's dimensionality). Returns whether it was installed.
    pub fn install_index(&self, idx: Box<dyn VectorIndex>) -> bool {
        if idx.dim() != self.dim {
            return false;
        }
        *self.index.write().unwrap() = idx;
        true
    }

    /// Whether the partition's index is HNSW-backed (recovery decides
    /// whether a persisted graph is applicable to the current config).
    pub fn index_is_hnsw(&self) -> bool {
        self.index.read().unwrap().is_hnsw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ManualClock;

    fn part(ttl: u64, capacity: usize) -> (Partition, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: ttl, capacity, ..Default::default() };
        (Partition::new(8, &cfg, clock.clone()), clock)
    }

    fn axis(i: usize) -> Vec<f32> {
        let mut v = vec![0.0; 8];
        v[i] = 1.0;
        v
    }

    fn entry(s: &str) -> CachedEntry {
        CachedEntry { question: s.into(), response: s.into(), cluster: 0, latency_ms: 0.0 }
    }

    #[test]
    fn expired_index_ids_are_skipped_and_tombstoned() {
        let (p, clock) = part(100, 0);
        p.insert(&axis(0), entry("old"));
        clock.advance(200);
        // Entry dead in store but still in index: lookup must miss.
        assert!(p.lookup(&axis(0), 0.8).is_none());
        // And a fresh same-direction insert must hit (index not poisoned).
        p.insert(&axis(0), entry("new"));
        let hit = p.lookup(&axis(0), 0.8).unwrap();
        assert_eq!(hit.entry.response, "new");
    }

    #[test]
    fn lru_eviction_consistency() {
        // Capacity 2 in a 1-shard-ish store: inserting 3 evicts one; the
        // evicted id must not be returned by lookups, and the eviction is
        // reported to the caller (the cache journals it).
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { capacity: 2, store_shards: 1, ..Default::default() };
        let p = Partition::new(8, &cfg, clock);
        let (a, ev) = p.insert_with_ttl(&axis(0), entry("a"), None);
        assert!(ev.is_empty());
        p.insert(&axis(1), entry("b"));
        let (_, ev) = p.insert_with_ttl(&axis(2), entry("c"), None); // evicts "a" (coldest)
        assert_eq!(ev, vec![a], "count eviction must surface the victim id");
        assert!(p.lookup(&axis(0), 0.8).is_none(), "evicted entry returned");
        assert!(p.lookup(&axis(1), 0.8).is_some());
        assert!(p.lookup(&axis(2), 0.8).is_some());
    }

    #[test]
    fn byte_accounting_and_policy_eviction() {
        let (p, _clock) = part(0, 0);
        let (a, _) = p.insert_with_ttl(&axis(0), entry("aaaa"), None);
        let (b, _) = p.insert_with_ttl(&axis(1), entry("bb"), None);
        let expect = entry_footprint(4, 4, 8) + entry_footprint(2, 2, 8);
        assert_eq!(p.bytes(), expect, "partition bytes = sum of entry footprints");
        // LRU victim is the older entry; evicting releases its bytes and
        // tombstones its index node.
        let v = p.victim(&crate::eviction::Lru).unwrap();
        assert_eq!(v.id, a);
        assert_eq!(p.evict_id(a), Some(entry_footprint(4, 4, 8)));
        assert_eq!(p.bytes(), entry_footprint(2, 2, 8));
        assert!(p.lookup(&axis(0), 0.8).is_none(), "evicted entry must not hit");
        assert!(p.lookup(&axis(1), 0.8).is_some());
        assert_eq!(p.victim(&crate::eviction::Lru).unwrap().id, b);
        assert_eq!(p.evict_id(a), None, "double-evict is a no-op");
    }

    #[test]
    fn sweep_tombstones_index_nodes_and_garbage_ratio_reflects_it() {
        // Regression (ISSUE 6 satellite): the pre-durability sweep only
        // emptied the KV store, leaving the partition's index nodes live
        // — expired entries kept steering searches and garbage_ratio()
        // under-counted until a lookup happened to trip over each dead
        // id. The unified sweep must tombstone store, index, and the
        // embedding map in one pass.
        let (p, clock) = part(100, 0);
        for i in 0..4 {
            p.insert(&axis(i), entry(&format!("dead{i}")));
        }
        clock.advance(200); // the first four expire at t=100
        for i in 4..8 {
            p.insert(&axis(i), entry(&format!("live{i}")));
        }
        assert_eq!(p.sweep_expired(), 4);
        assert_eq!(p.len(), 4);
        // 8 index slots (4 tombstoned), 4 live: the garbage is visible
        // immediately, without any lookup having touched the dead ids.
        assert!(
            (p.garbage_ratio() - 0.5).abs() < 1e-9,
            "garbage_ratio must count swept index nodes, got {}",
            p.garbage_ratio()
        );
        for i in 0..4 {
            assert!(p.lookup(&axis(i), 0.9).is_none(), "swept direction {i} must miss");
        }
        for i in 4..8 {
            assert!(p.lookup(&axis(i), 0.9).is_some(), "live direction {i} must hit");
        }
        // Rebuild reclaims the tombstones entirely.
        assert!(p.rebuild());
        assert_eq!(p.garbage_ratio(), 0.0);
        // A second sweep finds nothing (idempotent).
        assert_eq!(p.sweep_expired(), 0);
    }

    #[test]
    fn rebuild_preserves_live_entries() {
        let (p, clock) = part(1_000, 0);
        for i in 0..8 {
            p.insert(&axis(i), entry(&format!("e{i}")));
        }
        clock.advance(500);
        assert!(p.rebuild());
        for i in 0..8 {
            assert!(p.lookup(&axis(i), 0.9).is_some(), "entry {i} lost by rebuild");
        }
    }
}
