//! One embedding-size partition: ANN index + TTL'd entry store.
//!
//! The index and the store can disagree transiently: the index may hold
//! ids whose store entry has expired (TTL) or been LRU-evicted. Lookups
//! treat such ids as dead — they are skipped (and the index tombstoned)
//! — and the housekeeping rebuild reclaims the slots. This mirrors the
//! paper's Redis-TTL + ANN-index split, where Redis expiry is the source
//! of truth (§2.7).
//!
//! Concurrency: the ANN index sits behind a read-mostly `RwLock`, so any
//! number of batch workers can search one partition in parallel; only
//! inserts, tombstoning of dead ids, and rebuilds take the write lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::index::{FlatIndex, HnswIndex, VectorIndex};
use crate::store::{Clock, KvStore, StoreConfig};

use super::{CacheConfig, CacheHit, CachedEntry, IndexKind};

pub struct Partition {
    dim: usize,
    /// Read-mostly: `search` under the shared lock, mutation under the
    /// exclusive lock.
    index: RwLock<Box<dyn VectorIndex>>,
    store: KvStore<CachedEntry>,
    next_id: AtomicU64,
    /// Embeddings of live entries, kept for rebuilds (id -> embedding).
    embeddings: Mutex<std::collections::HashMap<u64, Vec<f32>>>,
    top_k: usize,
}

fn key(id: u64) -> String {
    format!("e{id:016x}")
}

impl Partition {
    pub fn new(dim: usize, cfg: &CacheConfig, clock: Arc<dyn Clock>) -> Self {
        let index: Box<dyn VectorIndex> = match cfg.index {
            IndexKind::Hnsw => Box::new(HnswIndex::new(dim, cfg.hnsw.clone())),
            IndexKind::Flat => Box::new(FlatIndex::new(dim)),
        };
        let store = KvStore::with_clock(
            StoreConfig {
                shards: cfg.store_shards,
                capacity: cfg.capacity,
                default_ttl_ms: cfg.ttl_ms,
            },
            clock,
        );
        Self {
            dim,
            index: RwLock::new(index),
            store,
            next_id: AtomicU64::new(1),
            embeddings: Mutex::new(std::collections::HashMap::new()),
            top_k: cfg.top_k.max(1),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn lookup(&self, embedding: &[f32], threshold: f32) -> Option<CacheHit> {
        self.lookup_k(embedding, threshold, None)
    }

    /// Lookup with a per-request candidate-set width (`None` = the
    /// configured `top_k`).
    pub fn lookup_k(
        &self,
        embedding: &[f32],
        threshold: f32,
        top_k: Option<usize>,
    ) -> Option<CacheHit> {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        let k = top_k.unwrap_or(self.top_k).max(1);
        let neighbors = {
            // Shared lock: concurrent lookups search in parallel.
            let index = self.index.read().unwrap();
            index.search(embedding, k)
        };
        for n in neighbors {
            if n.score < threshold {
                break; // results are sorted; nothing below can pass
            }
            match self.store.get(&key(n.id)) {
                Some(entry) => {
                    return Some(CacheHit { entry, score: n.score, id: n.id });
                }
                None => {
                    // Expired/evicted in the store: tombstone the index id
                    // so future searches skip it; rebuild reclaims later.
                    self.index.write().unwrap().remove(n.id);
                    self.embeddings.lock().unwrap().remove(&n.id);
                }
            }
        }
        None
    }

    pub fn insert(&self, embedding: &[f32], entry: CachedEntry) -> u64 {
        self.insert_with_ttl(embedding, entry, None)
    }

    /// Insert with a per-entry TTL override (`None` = store default,
    /// `Some(0)` = immortal).
    pub fn insert_with_ttl(
        &self,
        embedding: &[f32],
        entry: CachedEntry,
        ttl_ms: Option<u64>,
    ) -> u64 {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match ttl_ms {
            Some(ttl) => self.store.set_ttl(&key(id), entry, ttl),
            None => self.store.set(&key(id), entry),
        }
        self.embeddings.lock().unwrap().insert(id, embedding.to_vec());
        self.index.write().unwrap().insert(id, embedding);
        id
    }

    /// Live entry count (store is the source of truth).
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// Drop expired store entries; returns the count.
    pub fn sweep_expired(&self) -> usize {
        self.store.sweep_expired()
    }

    /// Tombstone fraction of the index (0 when empty).
    pub fn garbage_ratio(&self) -> f64 {
        let index = self.index.read().unwrap();
        let live = self.store.len();
        let slots = index.len().max(live);
        // Index len() counts non-tombstoned nodes; entries expired in the
        // store but still live in the index also count as garbage.
        if slots == 0 {
            return 0.0;
        }
        1.0 - live as f64 / slots as f64
    }

    /// Rebuild the index from live store entries; true if rebuilt.
    pub fn rebuild(&self) -> bool {
        // Collect live ids from the store.
        let mut live: Vec<(u64, Vec<f32>)> = Vec::new();
        {
            let embeddings = self.embeddings.lock().unwrap();
            self.store.for_each(|k, _| {
                if let Ok(id) = u64::from_str_radix(&k[1..], 16) {
                    if let Some(e) = embeddings.get(&id) {
                        live.push((id, e.clone()));
                    }
                }
            });
        }
        let mut index = self.index.write().unwrap();
        if index.len() == 0 && live.is_empty() {
            return false;
        }
        // Recreate the same concrete index kind, populated with live rows.
        let mut fresh: Box<dyn VectorIndex> = if index.is_hnsw() {
            Box::new(HnswIndex::new(self.dim, index.hnsw_config().expect("hnsw").clone()))
        } else {
            Box::new(FlatIndex::new(self.dim))
        };
        for (id, e) in &live {
            fresh.insert(*id, e);
        }
        *index = fresh;
        // Drop embeddings of dead ids.
        let live_ids: std::collections::HashSet<u64> = live.iter().map(|(id, _)| *id).collect();
        self.embeddings.lock().unwrap().retain(|id, _| live_ids.contains(id));
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ManualClock;

    fn part(ttl: u64, capacity: usize) -> (Partition, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { ttl_ms: ttl, capacity, ..Default::default() };
        (Partition::new(8, &cfg, clock.clone()), clock)
    }

    fn axis(i: usize) -> Vec<f32> {
        let mut v = vec![0.0; 8];
        v[i] = 1.0;
        v
    }

    fn entry(s: &str) -> CachedEntry {
        CachedEntry { question: s.into(), response: s.into(), cluster: 0 }
    }

    #[test]
    fn expired_index_ids_are_skipped_and_tombstoned() {
        let (p, clock) = part(100, 0);
        p.insert(&axis(0), entry("old"));
        clock.advance(200);
        // Entry dead in store but still in index: lookup must miss.
        assert!(p.lookup(&axis(0), 0.8).is_none());
        // And a fresh same-direction insert must hit (index not poisoned).
        p.insert(&axis(0), entry("new"));
        let hit = p.lookup(&axis(0), 0.8).unwrap();
        assert_eq!(hit.entry.response, "new");
    }

    #[test]
    fn lru_eviction_consistency() {
        // Capacity 2 in a 1-shard-ish store: inserting 3 evicts one; the
        // evicted id must not be returned by lookups.
        let clock = Arc::new(ManualClock::new(0));
        let cfg = CacheConfig { capacity: 2, store_shards: 1, ..Default::default() };
        let p = Partition::new(8, &cfg, clock);
        p.insert(&axis(0), entry("a"));
        p.insert(&axis(1), entry("b"));
        p.insert(&axis(2), entry("c")); // evicts "a" (coldest)
        assert!(p.lookup(&axis(0), 0.8).is_none(), "evicted entry returned");
        assert!(p.lookup(&axis(1), 0.8).is_some());
        assert!(p.lookup(&axis(2), 0.8).is_some());
    }

    #[test]
    fn rebuild_preserves_live_entries() {
        let (p, clock) = part(1_000, 0);
        for i in 0..8 {
            p.insert(&axis(i), entry(&format!("e{i}")));
        }
        clock.advance(500);
        assert!(p.rebuild());
        for i in 0..8 {
            assert!(p.lookup(&axis(i), 0.9).is_some(), "entry {i} lost by rebuild");
        }
    }
}
