//! Pluggable eviction policies over byte-accurate footprint accounting.
//!
//! The store used to bound memory by raw entry count with a hardwired
//! LRU. This module replaces that with a *byte budget*: every cached
//! entry charges a deterministic footprint ([`entry_footprint`]) against
//! the cache's global `max_bytes` and its tenant's quota, and when a
//! budget is exceeded a policy ([`EvictionPolicy`]) scores the resident
//! entries and the lowest-scoring one is evicted. Three policies ship:
//!
//! * **lru** — score is the last-access stamp; coldest entry goes first
//!   (the pre-tenancy behaviour, generalized to bytes).
//! * **lfu** — score is the access count; rarely-hit entries go first.
//! * **cost** — score is simulated-LLM-latency-saved per byte
//!   (`latency_ms / bytes`): the cache keeps the entries whose hits
//!   avoid the most upstream latency per byte of budget they occupy.
//!   The latency is the one recorded on the entry when its miss was
//!   served ([`crate::cache::CachedEntry::latency_ms`]).
//!
//! Expired-but-unswept entries always score below every live entry
//! (negative infinity), so budgets reclaim dead weight first.
//!
//! Scores are compared as (score, last-access stamp) — lower evicts
//! first — which makes LFU and cost ties deterministic (colder loses).

use std::sync::Arc;

use crate::error::{bail, Result};

/// Fixed per-entry bookkeeping charge: store key + hash-map slot + TTL /
/// access metadata + the id ↔ embedding map entry. A deliberate round
/// estimate — the point is that every entry pays the same recomputable
/// constant, not allocator-exact bytes.
pub const ENTRY_OVERHEAD_BYTES: u64 = 160;

/// Estimated ANN index node charge (HNSW links + level bookkeeping, or
/// a flat-index row header). Same deliberate-estimate caveat as
/// [`ENTRY_OVERHEAD_BYTES`].
pub const INDEX_NODE_BYTES: u64 = 96;

/// The byte footprint one cached entry charges against its budgets,
/// term by term (re-derived for the quantized-scan representation —
/// the old "2 embedding copies" constant went stale the moment a third
/// copy appeared):
///
/// * question + response text bytes;
/// * two f32 embedding copies (`dim * 4` each: one in the index
///   matrix, one in the rebuild map);
/// * one int8 embedding copy + its f32 scale (`dim + 4`): the
///   quantized code row every index row now carries;
/// * the index node estimate + the fixed per-entry overhead.
///
/// Deterministic in the entry's contents so accounting can be
/// recomputed and audited (the byte-accounting property test does
/// exactly that).
pub fn entry_footprint(question_len: usize, response_len: usize, dim: usize) -> u64 {
    question_len as u64
        + response_len as u64
        + 2 * (dim as u64) * 4
        + (dim as u64 + 4)
        + INDEX_NODE_BYTES
        + ENTRY_OVERHEAD_BYTES
}

/// Per-entry facts a policy may score on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EntryMeta {
    /// Footprint charged at insert ([`entry_footprint`]).
    pub bytes: u64,
    /// Monotonic stamp of the last access (insert or hit); larger =
    /// hotter.
    pub last_access_seq: u64,
    /// Number of accesses (insert counts as the first).
    pub access_count: u64,
    /// Simulated upstream latency a hit on this entry saves, ms.
    pub latency_saved_ms: f64,
}

/// An eviction policy: maps entry metadata to a score. When a byte
/// budget is exceeded, the resident entry with the *lowest*
/// (score, last-access stamp) is evicted, repeatedly, until the budget
/// holds again.
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Lower = evicted earlier. Must be deterministic in `meta`.
    fn score(&self, meta: &EntryMeta) -> f64;
}

/// Least-recently-used: evict the coldest entry.
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn score(&self, meta: &EntryMeta) -> f64 {
        meta.last_access_seq as f64
    }
}

/// Least-frequently-used: evict the entry with the fewest accesses.
pub struct Lfu;

impl EvictionPolicy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn score(&self, meta: &EntryMeta) -> f64 {
        meta.access_count as f64
    }
}

/// Cost-aware: evict the entry that saves the least simulated LLM
/// latency per byte of budget it occupies.
pub struct CostAware;

impl EvictionPolicy for CostAware {
    fn name(&self) -> &'static str {
        "cost"
    }

    fn score(&self, meta: &EntryMeta) -> f64 {
        meta.latency_saved_ms / meta.bytes.max(1) as f64
    }
}

/// Resolve a policy by its config name (`eviction_policy` key).
pub fn policy_from_name(name: &str) -> Result<Arc<dyn EvictionPolicy>> {
    match name {
        "lru" => Ok(Arc::new(Lru)),
        "lfu" => Ok(Arc::new(Lfu)),
        "cost" => Ok(Arc::new(CostAware)),
        other => bail!("eviction_policy must be lru|lfu|cost, got '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(bytes: u64, seq: u64, count: u64, latency: f64) -> EntryMeta {
        EntryMeta { bytes, last_access_seq: seq, access_count: count, latency_saved_ms: latency }
    }

    #[test]
    fn footprint_is_deterministic_and_monotonic() {
        let base = entry_footprint(0, 0, 0);
        // dim = 0 still pays the 4-byte quantization scale.
        assert_eq!(base, ENTRY_OVERHEAD_BYTES + INDEX_NODE_BYTES + 4);
        // dim = 8: two f32 copies (64) + one int8 copy (8); the scale
        // is already in `base`.
        assert_eq!(entry_footprint(10, 20, 8), base + 10 + 20 + 64 + 8);
        // Same inputs, same charge — the accounting must be auditable.
        assert_eq!(entry_footprint(7, 3, 96), entry_footprint(7, 3, 96));
        assert!(entry_footprint(100, 0, 8) > entry_footprint(10, 0, 8));
        // The quantized copy is charged per dimension: 9 bytes/dim
        // (2×4 f32 + 1 int8) beyond the fixed terms.
        assert_eq!(entry_footprint(0, 0, 96) - entry_footprint(0, 0, 0), 96 * 9);
    }

    #[test]
    fn policies_order_victims_as_documented() {
        let cold_rare_cheap = meta(100, 1, 1, 10.0);
        let hot_frequent_pricey = meta(100, 9, 9, 5_000.0);
        for (policy, name) in [
            (&Lru as &dyn EvictionPolicy, "lru"),
            (&Lfu, "lfu"),
            (&CostAware, "cost"),
        ] {
            assert_eq!(policy.name(), name);
            assert!(
                policy.score(&cold_rare_cheap) < policy.score(&hot_frequent_pricey),
                "{name} must evict the cold/rare/cheap entry first"
            );
        }
        // Cost-aware specifically: a big cheap entry loses to a small
        // expensive one even when the big one is hotter.
        let big_cheap_hot = meta(10_000, 9, 9, 100.0);
        let small_pricey_cold = meta(500, 1, 1, 2_000.0);
        assert!(CostAware.score(&big_cheap_hot) < CostAware.score(&small_pricey_cold));
    }

    #[test]
    fn policy_names_resolve_and_bad_names_reject() {
        for name in ["lru", "lfu", "cost"] {
            assert_eq!(policy_from_name(name).unwrap().name(), name);
        }
        assert!(policy_from_name("fifo").is_err());
        assert!(policy_from_name("").is_err());
    }
}
