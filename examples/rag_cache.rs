//! RAG front-cache (paper §6.2): deduplicating semantically repeated
//! retrieval-augmented queries before they reach the expensive
//! generate-with-context pipeline.
//!
//! Models a document-QA system where many users ask variations of the
//! same analytical questions ("summarize the financial trends for Q3
//! 2024"). The semantic cache sits in front of the RAG pipeline; repeated
//! intents skip both retrieval and generation.
//!
//! `cargo run --release --example rag_cache`

use std::sync::Arc;

use semcache::cache::{CacheConfig, SemanticCache};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::llm::{SimLlm, SimLlmConfig};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};

/// A (simulated) RAG pipeline: retrieval + long-context generation. The
/// latency model is deliberately heavier than plain chat (two stages).
struct RagPipeline {
    retriever_ms: f64,
    generator: SimLlm,
}

impl RagPipeline {
    fn answer(&self, query: &str) -> (String, f64) {
        let r = self.generator.call(query, None);
        (r.text, self.retriever_ms + r.latency_ms)
    }
}

fn main() -> semcache::error::Result<()> {
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };
    let cache = SemanticCache::new(CacheConfig { threshold: 0.8, ..Default::default() });
    let rag = RagPipeline {
        retriever_ms: 85.0,
        generator: SimLlm::new(SimLlmConfig { mean_output_tokens: 250.0, ..Default::default() }),
    };

    // Analyst queries: clusters of paraphrased intents.
    let queries = [
        "summarize the financial trends for q3 2024",
        "give me a summary of q3 2024 financial trends",
        "what were the financial trends in q3 2024",
        "list the key risks in the latest annual report",
        "what are the key risks from the latest annual report",
        "compare revenue growth between emea and apac",
        "how does revenue growth compare between emea and apac",
        "summarize the financial trends for q3 2024",
    ];

    let mut pipeline_ms = 0.0;
    let mut served_ms = 0.0;
    let mut rag_calls = 0;
    for q in &queries {
        let e = encoder.encode_text(q);
        let (source, ms) = match cache.lookup(&e) {
            Some(_hit) => ("cache", 0.5), // embed+lookup measured path
            None => {
                rag_calls += 1;
                let (answer, ms) = rag.answer(q);
                cache.try_insert(q, &e, &answer).expect("insert RAG answer");
                ("RAG", ms)
            }
        };
        // The no-cache baseline always pays the pipeline.
        let (_, baseline_ms) = rag.answer(q);
        pipeline_ms += baseline_ms;
        served_ms += ms;
        println!("{source:>5}  {ms:>8.1} ms  {q}");
    }

    println!("\nRAG pipeline invocations: {rag_calls}/{} queries", queries.len());
    println!(
        "total latency: {served_ms:.0} ms with cache vs {pipeline_ms:.0} ms without ({:.1}x)",
        pipeline_ms / served_ms.max(1e-9)
    );
    assert!(rag_calls < queries.len(), "paraphrases must be deduplicated");
    Ok(())
}
