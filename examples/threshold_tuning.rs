//! Threshold tuning (paper §5.3 + §2.10): sweep the similarity gate and
//! demonstrate the adaptive-threshold controller converging after a
//! burst of inaccurate hits.
//!
//! `cargo run --release --example threshold_tuning`

use semcache::cache::AdaptiveThreshold;
use semcache::embedding::NativeEncoder;
use semcache::experiments::{sweep_grid, threshold_sweep, EvalContext};
use semcache::llm::JudgeConfig;
use semcache::runtime::ModelParams;
use semcache::workload::DatasetConfig;

fn main() {
    // Small-scale sweep with the native encoder (fast, no artifacts).
    println!("building evaluation context (small scale)...");
    let enc = NativeEncoder::new(ModelParams::default());
    let ctx = EvalContext::build(&enc, &DatasetConfig::tiny(), 0x7013);

    let rows = threshold_sweep(
        &ctx,
        &Default::default(),
        &JudgeConfig::default(),
        &sweep_grid(),
    );
    println!("\nθ     hit-rate  positive-rate");
    for r in &rows {
        println!(
            "{:.2}  {:>7.1}%  {:>12.1}%",
            r.threshold,
            100.0 * r.hit_rate(),
            100.0 * r.positive_rate()
        );
    }

    // Adaptive controller demo (§2.10 "Dynamic Threshold Adjustment"):
    // a run of negative hits pushes the gate up; sustained accuracy
    // relaxes it slowly.
    println!("\nadaptive threshold controller:");
    let mut adaptive = AdaptiveThreshold::new(0.80);
    print!("start {:.3}", adaptive.get());
    for _ in 0..4 {
        adaptive.observe(false); // judge flagged bad hits
    }
    print!(" -> after 4 negatives {:.3}", adaptive.get());
    for _ in 0..200 {
        adaptive.observe(true); // long accurate streak
    }
    println!(" -> after 200 positives {:.3}", adaptive.get());
}
