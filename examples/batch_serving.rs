//! Batch serving pipeline demo: one `serve_batch` call serving a whole
//! burst of typed requests through the concurrent coordinator.
//!
//! The burst is embedded in amortized chunks, fanned out across a scoped
//! worker pool (concurrent ANN lookups under the cache's read-mostly
//! `RwLock` sharding), and merged back in input order; the per-stage
//! latency lands in the metrics registry, printed at the end.
//!
//! `cargo run --release --example batch_serving`

use std::sync::Arc;

use semcache::api::{Outcome, QueryRequest};
use semcache::coordinator::{Coordinator, ServerConfig};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};
use semcache::workload::{Category, DatasetConfig, WorkloadGenerator};

fn main() -> semcache::error::Result<()> {
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };
    let server = Arc::new(Coordinator::new(
        encoder,
        ServerConfig::builder().workers(4).build()?,
    ));

    // Knowledge base: the shopping-QA category of the synthetic workload.
    let ds = WorkloadGenerator::new(0xBA7C4).generate(&DatasetConfig::tiny());
    let kb: Vec<_> = ds.base_for(Category::ShoppingQa).cloned().collect();
    println!("populating cache with {} QA pairs...", kb.len());
    server.populate(&kb);
    server.register_ground_truth(&ds);

    // A burst of queries arrives at once: serve it as ONE batch of typed
    // requests (ground-truth clusters attached for judge evaluation).
    let burst: Vec<_> = ds.tests_for(Category::ShoppingQa).cloned().collect();
    let reqs: Vec<QueryRequest> = burst
        .iter()
        .map(|q| QueryRequest::new(q.text.as_str()).with_cluster(q.answer_group))
        .collect();
    println!("serving a burst of {} queries via serve_batch (4 workers)...\n", reqs.len());
    let replies = server.serve_batch(&reqs);

    for (q, r) in burst.iter().zip(&replies) {
        let tag = match r.outcome {
            Outcome::Hit { score, .. } => format!("HIT  {score:.3}"),
            Outcome::Miss { .. } => format!("MISS {:>5.0}ms", r.latency.llm_ms),
            Outcome::Rejected { .. } => "REJECTED".to_string(),
        };
        println!("  [{tag}]  {}", q.text);
    }

    let m = server.metrics().snapshot();
    println!(
        "\nbatch metrics: {} batch / {} queries, hit rate {:.0}%",
        m.batches,
        m.batch_queries,
        100.0 * m.hit_rate()
    );
    println!(
        "stage latency: embed {:.1} ms (summed chunks), merge {:.3} ms, end-to-end {:.1} ms",
        m.lat_batch_embed.mean, m.lat_batch_merge.mean, m.lat_batch_total.mean
    );
    println!(
        "per-query means: embed {:.2} ms, ANN lookup {:.3} ms, llm {:.1} ms",
        m.lat_embed.mean, m.lat_index.mean, m.lat_llm.mean
    );
    Ok(())
}
