//! Quickstart: the paper's §2.5 workflow over the typed v1 API.
//!
//! Run with `cargo run --release --example quickstart`.
//! Uses the PJRT (AOT) encoder when `make artifacts` has been run,
//! otherwise falls back to the pure-Rust native encoder.

use std::sync::Arc;

use semcache::api::{Outcome, QueryRequest};
use semcache::coordinator::{Server, ServerConfig};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};

fn main() -> semcache::error::Result<()> {
    // 1. Pick an embedding backend (paper §2.2: pluggable models).
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        println!("using AOT JAX/Pallas encoder via PJRT");
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        println!("artifacts not built; using native encoder (run `make artifacts`)");
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };

    // 2. Stand up the cache-fronted server (simulated GPT upstream) via
    //    the validating config builder.
    let server = Server::new(encoder, ServerConfig::builder().workers(4).build()?);

    // 3. First query: cache miss -> LLM -> cached (typed outcome).
    let q1 = "How do I reset my online banking password?";
    let r1 = server.serve(&QueryRequest::new(q1));
    println!("\nQ1: {q1}\n  -> {}, {:.1} ms (llm {:.1} ms)", kind(&r1.outcome), r1.latency.total_ms, r1.latency.llm_ms);

    // 4. Semantically similar query: served from the cache, no API call.
    let q2 = "How can I reset my password for online banking?";
    let r2 = server.serve(&QueryRequest::new(q2));
    println!("Q2: {q2}\n  -> {}, {:.2} ms", kind(&r2.outcome), r2.latency.total_ms);
    if let Outcome::Hit { score, entry_id } = r2.outcome {
        println!("  cosine similarity of match: {score:.3} (entry #{entry_id})");
    }
    assert_eq!(r1.response, r2.response, "cached response reused");

    // 5. Unrelated query: correctly misses.
    let q3 = "What is the capital of France?";
    let r3 = server.serve(&QueryRequest::new(q3));
    println!("Q3: {q3}\n  -> {}", kind(&r3.outcome));

    let m = server.metrics().snapshot();
    println!(
        "\nmetrics: {} requests, {} cache hits, {} LLM calls (hit rate {:.0}%)",
        m.requests,
        m.cache_hits,
        m.llm_calls,
        100.0 * m.hit_rate()
    );
    println!(
        "speedup on the cached query: {:.0}x",
        r1.latency.total_ms / r2.latency.total_ms.max(1e-9)
    );
    Ok(())
}

fn kind(o: &Outcome) -> &'static str {
    match o {
        Outcome::Hit { .. } => "CACHE HIT",
        Outcome::Miss { .. } => "LLM CALL",
        Outcome::Rejected { .. } => "REJECTED",
    }
}
