//! Quickstart: the paper's §2.5 workflow in ~40 lines.
//!
//! Run with `cargo run --release --example quickstart`.
//! Uses the PJRT (AOT) encoder when `make artifacts` has been run,
//! otherwise falls back to the pure-Rust native encoder.

use std::sync::Arc;

use semcache::coordinator::{ReplySource, Server, ServerConfig};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};

fn main() -> semcache::error::Result<()> {
    // 1. Pick an embedding backend (paper §2.2: pluggable models).
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        println!("using AOT JAX/Pallas encoder via PJRT");
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        println!("artifacts not built; using native encoder (run `make artifacts`)");
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };

    // 2. Stand up the cache-fronted server (simulated GPT upstream).
    let server = Server::new(encoder, ServerConfig::default());

    // 3. First query: cache miss -> LLM -> cached.
    let q1 = "How do I reset my online banking password?";
    let r1 = server.handle(q1, None);
    println!("\nQ1: {q1}\n  -> {:?}, {:.1} ms (llm {:.1} ms)", kind(&r1.source), r1.total_ms, r1.llm_ms);

    // 4. Semantically similar query: served from the cache, no API call.
    let q2 = "How can I reset my password for online banking?";
    let r2 = server.handle(q2, None);
    println!("Q2: {q2}\n  -> {:?}, {:.2} ms", kind(&r2.source), r2.total_ms);
    if let ReplySource::Cache { score } = r2.source {
        println!("  cosine similarity of match: {score:.3}");
    }
    assert_eq!(r1.response, r2.response, "cached response reused");

    // 5. Unrelated query: correctly misses.
    let q3 = "What is the capital of France?";
    let r3 = server.handle(q3, None);
    println!("Q3: {q3}\n  -> {:?}", kind(&r3.source));

    let m = server.metrics().snapshot();
    println!(
        "\nmetrics: {} requests, {} cache hits, {} LLM calls (hit rate {:.0}%)",
        m.requests,
        m.cache_hits,
        m.llm_calls,
        100.0 * m.hit_rate()
    );
    println!(
        "speedup on the cached query: {:.0}x",
        r1.total_ms / r2.total_ms.max(1e-9)
    );
    Ok(())
}

fn kind(s: &ReplySource) -> &'static str {
    match s {
        ReplySource::Cache { .. } => "CACHE HIT",
        ReplySource::Llm => "LLM CALL",
    }
}
