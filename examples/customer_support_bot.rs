//! Customer-support chatbot (paper §6.1): a live serving demo.
//!
//! Populates the cache with the order-and-shipping knowledge base, then
//! replays a bursty customer trace through the multi-worker coordinator
//! with Poisson arrivals, printing the serving report — the scenario the
//! paper's intro motivates (repetitive support questions).
//!
//! `cargo run --release --example customer_support_bot`

use std::sync::Arc;
use std::time::Duration;

use semcache::cache::CacheConfig;
use semcache::coordinator::{Server, ServerConfig, TraceConfig, TraceRunner};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::llm::SimLlmConfig;
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};
use semcache::workload::{Category, DatasetConfig, WorkloadGenerator};

fn main() -> semcache::error::Result<()> {
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };

    // TTL + bounded cache: the production-ish configuration (§2.7),
    // assembled through the validating builders. (This demo serves
    // through TraceRunner's per-query path; the batch-pipeline pool
    // width is TraceConfig::workers below.)
    let server = Arc::new(Server::new(
        encoder,
        ServerConfig::builder()
            .cache(CacheConfig::builder().ttl_ms(3_600_000).capacity(50_000).build()?)
            .llm(SimLlmConfig::default())
            .build()?,
    ));

    // Knowledge base: shipping-category QA pairs only.
    let ds = WorkloadGenerator::new(0xB07).generate(&DatasetConfig::small());
    let kb: Vec<_> = ds.base_for(Category::OrderShipping).cloned().collect();
    println!("populating support knowledge base: {} QA pairs", kb.len());
    server.populate(&kb);
    server.register_ground_truth(&ds);
    let _hk = server.start_housekeeping(Duration::from_millis(500));

    // Customer trace: shipping test queries, replayed with 8 workers.
    let trace: Vec<_> = ds.tests_for(Category::OrderShipping).cloned().collect();
    println!("replaying {} customer queries through 8 workers...", trace.len());
    let report = TraceRunner::new(server.clone()).run(
        &trace,
        &TraceConfig { workers: 8, qps: 0.0, use_cache: true, seed: 7 },
    );

    println!("\n=== serving report ===");
    println!(
        "answered {} queries in {:.2}s wall ({:.0} qps)",
        report.replies.len(),
        report.wall_secs,
        report.throughput_qps
    );
    println!(
        "cache hits: {} ({:.1}%), LLM calls: {}",
        report.hits,
        100.0 * report.hits as f64 / report.replies.len() as f64,
        report.misses
    );
    println!(
        "user-visible latency (incl. simulated LLM time): mean {:.1} ms, p50 {:.2} ms, p95 {:.1} ms",
        report.latency.mean, report.latency.p50, report.latency.p95
    );
    let m = server.metrics().snapshot();
    println!(
        "hit accuracy (judged): {:.1}%  |  est. spend ${:.4}",
        100.0 * m.positive_rate(),
        m.cost_usd(&Default::default())
    );
    Ok(())
}
