//! Real-time code assistant (paper §6.3): caching code-help prompts.
//!
//! The paper's example: "How do I write a function to reverse a string
//! in Python?" should reuse the cached answer for "Python function to
//! reverse text". This demo also exercises the TTL mechanism: cached
//! answers expire so stale API docs don't persist (§2.7).
//!
//! `cargo run --release --example code_assistant`

use std::sync::Arc;

use semcache::cache::{CacheConfig, SemanticCache};
use semcache::embedding::{BatcherConfig, EmbeddingService, Encoder, EncoderSpec, NativeEncoder};
use semcache::llm::{SimLlm, SimLlmConfig};
use semcache::runtime::{artifacts_dir, pjrt_ready, ModelParams};
use semcache::store::ManualClock;

fn main() -> semcache::error::Result<()> {
    let encoder: Arc<dyn Encoder> = if pjrt_ready() {
        Arc::new(EmbeddingService::spawn(
            EncoderSpec::Pjrt(artifacts_dir()),
            BatcherConfig::default(),
        )?)
    } else {
        Arc::new(NativeEncoder::new(ModelParams::default()))
    };

    // Manual clock so the demo can fast-forward past the TTL.
    let clock = Arc::new(ManualClock::new(0));
    let cache = SemanticCache::with_clock(
        CacheConfig { ttl_ms: 30 * 60 * 1000, ..Default::default() }, // 30 min TTL
        clock.clone(),
    );
    let llm = SimLlm::new(SimLlmConfig::default());

    let mut ask = |cache: &SemanticCache, prompt: &str| -> (String, bool) {
        let e = encoder.encode_text(prompt);
        match cache.lookup(&e) {
            Some(hit) => {
                println!("HIT  ({:.3})  {prompt}", hit.score);
                (hit.entry.response, true)
            }
            None => {
                let r = llm.call(prompt, None);
                cache.try_insert(prompt, &e, &r.text).expect("insert completion");
                println!("MISS ({:>5.0} ms simulated LLM)  {prompt}", r.latency_ms);
                (r.text, false)
            }
        }
    };

    println!("--- developer session ---");
    let (a1, hit1) = ask(&cache, "how do i write a function to reverse a string in python");
    assert!(!hit1);
    // The paper's paraphrase example reuses the cached completion:
    let (a2, hit2) = ask(&cache, "write a python function to reverse a string");
    assert!(hit2, "paraphrase should reuse the cached completion");
    assert_eq!(a1, a2);

    let (_, hit3) = ask(&cache, "how do i debug a segfault in my c extension");
    assert!(!hit3, "unrelated prompt must go to the LLM");

    // Same question a few minutes later: still cached.
    clock.advance(5 * 60 * 1000);
    let (_, hit4) = ask(&cache, "how do i write a function to reverse a string with python");
    assert!(hit4);

    // After the TTL expires the entry is refreshed from the LLM (§2.7).
    clock.advance(40 * 60 * 1000);
    println!("--- 40 minutes later (TTL = 30 min) ---");
    let (_, hit5) = ask(&cache, "how do i write a function to reverse a string in python");
    assert!(!hit5, "expired entry must be refreshed, not served stale");

    println!("\ncache size after session: {} entries", cache.len());
    Ok(())
}
