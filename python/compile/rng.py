"""splitmix64 PRNG — the bit-exact twin of ``rust/src/util/rng.rs``.

Every encoder weight tensor is *generated*, not trained: both the JAX
compile path (this file) and the Rust native encoder derive all parameters
from the same named splitmix64 streams, so the two implementations agree
without shipping a checkpoint. Any change here must be mirrored in Rust;
the cross-language contract is pinned by known-answer tests on both sides
(``python/tests/test_rng.py`` and ``util::rng::tests``).
"""

from __future__ import annotations

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)

_U64 = np.uint64
_INV_2_53 = 1.0 / 9007199254740992.0  # 2**-53


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit (twin: ``tokenizer::hash::fnv1a64``)."""
    h = _FNV_OFFSET
    with np.errstate(over="ignore"):
        for b in data:
            h = _U64(h ^ _U64(b)) * _FNV_PRIME
    return int(h)


def derive_seed(seed: int, label: str) -> int:
    """Independent stream seed for a named tensor (twin: ``SplitMix64::derive``)."""
    return int(_U64(seed) ^ _U64(fnv1a64(label.encode("utf-8"))))


def splitmix64_block(seed: int, n: int) -> np.ndarray:
    """The first ``n`` outputs of splitmix64(seed), vectorized.

    state_i = seed + (i+1) * GOLDEN; output_i = mix(state_i) — identical to
    the sequential Rust loop.
    """
    with np.errstate(over="ignore"):
        i = np.arange(1, n + 1, dtype=np.uint64)
        z = _U64(seed) + i * _GOLDEN
        z = (z ^ (z >> _U64(30))) * _MIX1
        z = (z ^ (z >> _U64(27))) * _MIX2
        return z ^ (z >> _U64(31))


def uniform53(bits: np.ndarray) -> np.ndarray:
    """u64 -> f64 in [0, 1): top 53 bits / 2^53 (twin: ``next_f64``)."""
    return (bits >> np.uint64(11)).astype(np.float64) * _INV_2_53


def normal(seed: int, n: int, std: float) -> np.ndarray:
    """``n`` N(0, std^2) floats — bit-exact twin of ``SplitMix64::fill_normal``.

    Rust consumes draws in pairs (u1, u2) and emits (r cos, r sin); the last
    pair of an odd-length fill emits only the cos half.
    """
    m = (n + 1) // 2
    bits = splitmix64_block(seed, 2 * m)
    u1 = 1.0 - uniform53(bits[0::2])
    u2 = uniform53(bits[1::2])
    r = np.sqrt(-2.0 * np.log(u1))
    theta = 2.0 * np.pi * u2
    out = np.empty(2 * m, dtype=np.float64)
    out[0::2] = r * np.cos(theta) * std
    out[1::2] = r * np.sin(theta) * std
    # float32 rounding happens element-wise in Rust ("as f32"); match it.
    return out[:n].astype(np.float32)


def normal_tensor(seed: int, label: str, shape: tuple[int, ...], std: float) -> np.ndarray:
    """Named tensor fill: derive the stream from (seed, label), row-major."""
    n = int(np.prod(shape))
    return normal(derive_seed(seed, label), n, std).reshape(shape)
