"""Deterministic hashing word tokenizer — twin of ``rust/src/tokenizer``.

The compile path only needs the tokenizer for tests and example traces;
the request path tokenizes in Rust. Both must agree exactly, so this file
mirrors the Rust algorithm line for line:

1. lowercase; split on anything outside ``[a-z0-9']``;
2. word id = ``2 + fnv1a64(word) % (vocab_size - 2)``;
3. ``[CLS] w0 w1 ...`` truncated / right-padded with PAD to ``seq_len``.
"""

from __future__ import annotations

from .rng import fnv1a64

PAD_ID = 0
CLS_ID = 1
FIRST_WORD_ID = 2


def words(text: str) -> list[str]:
    """Normalized word split (twin: ``Tokenizer::words``)."""
    out: list[str] = []
    cur: list[str] = []
    for ch in text:
        # ASCII-only lowercase: Rust uses to_ascii_lowercase, and Python's
        # .lower() would diverge on chars like 'K' (U+212A) → 'k'.
        c = ch.lower() if "A" <= ch <= "Z" else ch
        if ("a" <= c <= "z") or ("0" <= c <= "9") or c == "'":
            cur.append(c)
        elif cur:
            out.append("".join(cur))
            cur = []
    if cur:
        out.append("".join(cur))
    return out


class Tokenizer:
    """Fixed-vocab, fixed-length tokenizer (twin: ``tokenizer::Tokenizer``)."""

    def __init__(self, vocab_size: int, seq_len: int):
        assert vocab_size > 2 and seq_len >= 2
        self.vocab_size = vocab_size
        self.seq_len = seq_len

    def word_id(self, word: str) -> int:
        return FIRST_WORD_ID + fnv1a64(word.encode("utf-8")) % (self.vocab_size - 2)

    def encode(self, text: str) -> list[int]:
        ids = [CLS_ID]
        for w in words(text):
            if len(ids) == self.seq_len:
                break
            ids.append(self.word_id(w))
        ids.extend([PAD_ID] * (self.seq_len - len(ids)))
        return ids

    def encode_batch(self, texts: list[str]) -> list[list[int]]:
        return [self.encode(t) for t in texts]
