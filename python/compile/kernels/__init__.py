"""L1 Pallas kernels (attention, scorer) and their pure-jnp oracles."""
