"""L1 Pallas kernel: blocked cosine scoring (the cache's similarity scan).

Scores one normalized query against a block-resident corpus matrix — the
exact-rerank / flat-baseline hot loop, expressed as the HBM→VMEM streaming
schedule the paper's CUDA-ish analogue would tile with threadblocks:

* the corpus (N, D) streams through VMEM in (BLOCK, D) tiles, one grid
  step each (BLOCK=256 → 256·384·4 B = 384 KiB per tile);
* the query vector is broadcast-resident across all steps
  (``index_map = 0``), living in VMEM for the whole sweep;
* each step emits a (BLOCK,) score slice; top-k reduction happens in the
  surrounding jax graph with ``lax.top_k`` (data-dependent selection is
  cheap at (N,) and keeps the kernel a pure streaming matvec the MXU can
  saturate).
"""

from __future__ import annotations

import functools

import jax
import jax.lax as lax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _score_kernel(q_ref, c_ref, o_ref):
    """One corpus tile: (BLOCK, D) @ (D,) -> (BLOCK,)."""
    o_ref[...] = c_ref[...] @ q_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scores(query, corpus, interpret: bool = True):
    """Cosine scores (N,) of `query` (D,) against `corpus` (N, D).

    Both inputs must be L2-normalized (cosine == dot). N must be a
    multiple of BLOCK — the AOT path compiles fixed-shape variants and the
    Rust caller pads the final tile.
    """
    n, d = corpus.shape
    assert n % BLOCK == 0, f"N={n} must be a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),        # query: resident
            pl.BlockSpec((BLOCK, d), lambda i: (i, 0)),  # corpus: streamed
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), corpus.dtype),
        interpret=interpret,
    )(query, corpus)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk(query, corpus, k: int, interpret: bool = True):
    """(values, indices) of the k best cosine scores; indices as f32.

    Implemented with a full descending argsort rather than ``lax.top_k``:
    top_k lowers to the ``topk(..., largest=true)`` HLO op, which the
    xla_extension 0.5.1 text parser used by the Rust runtime predates.
    A comparator ``sort`` parses cleanly and costs the same at N ≤ 4096.

    Indices are cast to f32 so the whole output tuple is homogeneous —
    the Rust runtime reads every output as f32 and rounds indices back.
    """
    s = scores(query, corpus, interpret=interpret)
    order = jnp.argsort(-s)[:k]
    return s[order], order.astype(jnp.float32)
