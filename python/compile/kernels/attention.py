"""L1 Pallas kernel: fused masked multi-head attention.

The encoder's compute hot-spot. One grid step per (batch, head); the
step's Q/K/V blocks and the (S, S) score matrix live entirely in VMEM, so
scores never round-trip HBM — the TPU re-thinking of a fused CUDA
attention kernel (DESIGN.md §6 Hardware-Adaptation):

* BlockSpec carves (B, H, S, Dh) into per-(b, h) (S, Dh) tiles — the
  HBM→VMEM schedule a CUDA kernel would express with threadblocks;
* the (S, S) = (32, 32) score tile and softmax stay in registers/VMEM;
* per-step VMEM footprint: 3·(32·64) + 32·32 + 32·64 floats ≈ 37 KiB,
  comfortably under the ~16 MiB/core budget; on a real TPU the 64-wide
  contractions map onto MXU tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and lowering in interpret mode produces plain HLO that the
Rust runtime runs directly (numerics are identical; perf on real TPU is
estimated in DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale: float):
    """One (batch, head) tile: softmax(q kᵀ · scale + mask) v, all in VMEM."""
    q = q_ref[0, 0]        # (S, Dh)
    k = k_ref[0, 0]        # (S, Dh)
    v = v_ref[0, 0]        # (S, Dh)
    m = mask_ref[0]        # (S,)  1.0 = real token, 0.0 = pad
    scores = jnp.dot(q, k.T) * scale           # (S, S) — stays in VMEM
    scores = scores + (1.0 - m)[None, :] * -1e9  # mask pad *keys*
    scores = scores - scores.max(axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(probs, v)            # (S, Dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q, k, v, mask, interpret: bool = True):
    """Fused MHA: q, k, v (B, H, S, Dh), mask (B, S) → (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    grid = (b, h)
    qkv_spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    mask_spec = pl.BlockSpec((1, s), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=grid,
        in_specs=[qkv_spec, qkv_spec, qkv_spec, mask_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)
