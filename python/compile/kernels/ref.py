"""Pure-jnp correctness oracles for the Pallas kernels.

Everything the L1 kernels compute is re-expressed here with plain
``jax.numpy`` ops. pytest asserts allclose between the two on swept shapes
(hypothesis), and the L2 model can be built entirely from these refs
(``use_pallas=False``) to isolate kernel bugs from model bugs.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, mask):
    """Masked multi-head scaled-dot-product attention.

    q, k, v: (B, H, S, Dh); mask: (B, S) with 1.0 = real token, 0.0 = pad.
    Returns (B, H, S, Dh). Pad *keys* are masked out; pad query rows
    produce garbage that pooling later ignores (same contract as the
    Pallas kernel).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    neg = jnp.asarray(-1e9, dtype=q.dtype)
    scores = scores + (1.0 - mask[:, None, None, :]) * neg
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def scores_ref(query, corpus):
    """Cosine scores of one normalized query against normalized corpus rows.

    query: (D,), corpus: (N, D) — both L2-normalized, so cosine == dot.
    Returns (N,).
    """
    return corpus @ query


def topk_ref(query, corpus, k):
    """Top-k (values, indices) by cosine score."""
    import jax.lax as lax

    return lax.top_k(scores_ref(query, corpus), k)
