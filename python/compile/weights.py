"""Generated encoder weights — the shared Python/Rust parameterization.

The encoder is a MiniLM-geometry transformer whose parameters are drawn
from named splitmix64 streams (see ``rng.py``). The Rust native encoder
(`embedding::native`) generates the *same* tensors from the same
``(seed, label, shape, std)`` table below; the table is therefore part of
the cross-language contract — change it in both places or not at all.

Initialization scales are chosen so the token-embedding (lexical) signal
dominates and the transformer adds contextual refinement on top:
output projections (wo, w2) are down-scaled 10x, positional encodings are
small. This keeps the generated encoder's similarity structure monotone in
lexical overlap — the property the semantic-cache experiments need (see
DESIGN.md §3, Embedding substitution).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import rng


@dataclass(frozen=True)
class ModelParams:
    """Encoder hyperparameters; must match ``runtime::manifest::ModelParams``."""

    vocab_size: int = 4096
    dim: int = 384
    hidden: int = 768
    layers: int = 4
    heads: int = 6
    seq_len: int = 32
    seed: int = 0x5EEDCAFE

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


def weight_table(p: ModelParams) -> list[tuple[str, tuple[int, ...], float]]:
    """(label, shape, std) for every tensor, in canonical order."""
    d, h, lyr = p.dim, p.hidden, p.layers
    inv_sqrt_d = 1.0 / np.sqrt(d)
    inv_sqrt_h = 1.0 / np.sqrt(h)
    return [
        ("embed", (p.vocab_size, d), 1.0),
        ("pos", (p.seq_len, d), 0.1),
        ("wq", (lyr, d, d), inv_sqrt_d),
        ("wk", (lyr, d, d), inv_sqrt_d),
        ("wv", (lyr, d, d), inv_sqrt_d),
        ("wo", (lyr, d, d), 0.1 * inv_sqrt_d),
        ("w1", (lyr, d, h), inv_sqrt_d),
        ("w2", (lyr, h, d), 0.1 * inv_sqrt_h),
    ]


def generate(p: ModelParams) -> dict[str, np.ndarray]:
    """All weight tensors as float32 numpy arrays, keyed by label."""
    return {
        label: rng.normal_tensor(p.seed, label, shape, std)
        for label, shape, std in weight_table(p)
    }


def flat_inputs(weights: dict[str, np.ndarray], p: ModelParams) -> list[np.ndarray]:
    """Weights in the positional order the AOT executable expects."""
    return [weights[label] for label, _, _ in weight_table(p)]
