"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.

Run once by ``make artifacts``; Python never touches the request path.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  encoder_b{1,4,8,16,32}.hlo.txt   sentence encoder at fixed batch sizes
  scorer_n{1024,4096}.hlo.txt      blocked cosine top-k scorer
  manifest.json                    name → file + I/O shapes + model params
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)  # i64 token ids end-to-end

from .kernels import scorer as scorer_kernel  # noqa: E402
from .model import make_encoder  # noqa: E402
from .weights import ModelParams, weight_table  # noqa: E402

ENCODER_BATCH_SIZES = (1, 4, 8, 16, 32)
SCORER_SIZES = (1024, 4096)
SCORER_TOPK = 16


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_encoder(p: ModelParams, batch: int) -> tuple[str, dict]:
    tokens = jax.ShapeDtypeStruct((batch, p.seq_len), jnp.int64)
    wspecs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape, _ in weight_table(p)
    ]
    encode = make_encoder(p, use_pallas=True, interpret=True)
    lowered = jax.jit(encode).lower(tokens, *wspecs)
    spec = {
        "name": f"encoder_b{batch}",
        "file": f"encoder_b{batch}.hlo.txt",
        "input_shapes": [[batch, p.seq_len]] + [list(s) for _, s, _ in weight_table(p)],
        "output_shapes": [[batch, p.dim]],
    }
    return to_hlo_text(lowered), spec


def lower_scorer(p: ModelParams, n: int, k: int) -> tuple[str, dict]:
    q = jax.ShapeDtypeStruct((p.dim,), jnp.float32)
    corpus = jax.ShapeDtypeStruct((n, p.dim), jnp.float32)

    def fn(q, corpus):
        return scorer_kernel.topk(q, corpus, k, interpret=True)

    lowered = jax.jit(fn).lower(q, corpus)
    spec = {
        "name": f"scorer_n{n}",
        "file": f"scorer_n{n}.hlo.txt",
        "input_shapes": [[p.dim], [n, p.dim]],
        "output_shapes": [[k], [k]],
    }
    return to_hlo_text(lowered), spec


def build(out_dir: str, p: ModelParams) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    for b in ENCODER_BATCH_SIZES:
        text, spec = lower_encoder(p, b)
        path = os.path.join(out_dir, spec["file"])
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(spec)
        print(f"  wrote {spec['file']} ({len(text) / 1024:.0f} KiB)")

    for n in SCORER_SIZES:
        text, spec = lower_scorer(p, n, SCORER_TOPK)
        path = os.path.join(out_dir, spec["file"])
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(spec)
        print(f"  wrote {spec['file']} ({len(text) / 1024:.0f} KiB)")

    manifest = {
        "artifacts": artifacts,
        "model": {
            "vocab_size": p.vocab_size,
            "dim": p.dim,
            "hidden": p.hidden,
            "layers": p.layers,
            "heads": p.heads,
            "seq_len": p.seq_len,
            "seed": p.seed,
        },
        "scorer_topk": SCORER_TOPK,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"  wrote manifest.json ({len(artifacts)} artifacts)")
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    p = ModelParams()
    print(f"AOT-lowering encoder ({p.layers}L x {p.dim}d, vocab {p.vocab_size}) "
          f"and scorer to {args.out}")
    build(args.out, p)
    return 0


if __name__ == "__main__":
    sys.exit(main())
