"""L2: the sentence encoder as a JAX computation.

MiniLM-geometry transformer (paper §2.2 uses all-MiniLM-L6-v2; see
DESIGN.md §3 for the generated-weights substitution):

    token-embed + pos → [pre-LN attention + pre-LN GELU FFN] × L
    → masked mean-pool → L2 normalize → (B, D) unit embeddings

Weights are *inputs* to the lowered function (not baked constants), so the
HLO stays small and the Rust runtime feeds the same generated tensors it
derives from the shared splitmix64 streams; they are pre-uploaded to
device buffers once at startup.

The attention hot-spot is the L1 Pallas kernel
(``kernels.attention``); ``use_pallas=False`` swaps in the pure-jnp
oracle so pytest can isolate kernel bugs from model bugs. Norm/GELU
formulas here are mirrored exactly by ``rust/src/embedding/native.rs``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ref as kref
from .weights import ModelParams

LN_EPS = 1e-6


def layer_norm(x):
    """Parameter-free LayerNorm over the last axis (eps mirrored in Rust)."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS)


def gelu(x):
    """tanh-approximate GELU — the exact formula the Rust twin implements."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def split_heads(x, heads: int):
    """(B, S, D) → (B, H, S, Dh)."""
    b, s, d = x.shape
    return x.reshape(b, s, heads, d // heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    """(B, H, S, Dh) → (B, S, D)."""
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def encoder_fwd(tokens, embed, pos, wq, wk, wv, wo, w1, w2, *,
                params: ModelParams, use_pallas: bool = True,
                interpret: bool = True):
    """Forward pass: token ids (B, S) int → unit embeddings (B, D) f32.

    Weight arguments follow ``weights.weight_table`` order; the stacked
    layer tensors (wq, ...) carry a leading ``layers`` axis.
    """
    p = params
    mask = (tokens != 0).astype(jnp.float32)              # (B, S)
    x = embed[tokens] + pos[None, :, :]                    # (B, S, D)
    for l in range(p.layers):
        h = layer_norm(x)
        q = split_heads(h @ wq[l], p.heads)
        k = split_heads(h @ wk[l], p.heads)
        v = split_heads(h @ wv[l], p.heads)
        if use_pallas:
            ctx = attn_kernel.attention(q, k, v, mask, interpret=interpret)
        else:
            ctx = kref.attention_ref(q, k, v, mask)
        x = x + merge_heads(ctx) @ wo[l]
        h = layer_norm(x)
        x = x + gelu(h @ w1[l]) @ w2[l]
    x = layer_norm(x)
    # Masked mean pool: pad rows contribute nothing.
    denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = (x * mask[:, :, None]).sum(axis=1) / denom    # (B, D)
    # L2 normalize (zero-safe, mirrored in Rust).
    norm = jnp.sqrt((pooled * pooled).sum(axis=-1, keepdims=True))
    return pooled / jnp.maximum(norm, 1e-12)


def make_encoder(params: ModelParams, use_pallas: bool = True,
                 interpret: bool = True):
    """A jit-able ``f(tokens, *weights) -> (embeddings,)`` closure.

    Returns a 1-tuple to match the rust loader's ``return_tuple=True``
    unwrapping convention.
    """

    @functools.partial(jax.jit)
    def encode(tokens, embed, pos, wq, wk, wv, wo, w1, w2):
        return (
            encoder_fwd(
                tokens, embed, pos, wq, wk, wv, wo, w1, w2,
                params=params, use_pallas=use_pallas, interpret=interpret,
            ),
        )

    return encode
