"""Tokenizer twin tests — mirrors ``rust/src/tokenizer/mod.rs`` exactly."""

from hypothesis import given, settings
from hypothesis import strategies as st

from compile.tokenizer import CLS_ID, PAD_ID, Tokenizer, words


def tok():
    return Tokenizer(4096, 32)


def test_splits_and_normalizes():
    assert words("How do I reset my-password?  ") == [
        "how", "do", "i", "reset", "my", "password",
    ]
    assert words("don't stop") == ["don't", "stop"]
    assert words("!!!") == []


def test_encode_shape_and_padding():
    ids = tok().encode("hello world")
    assert len(ids) == 32
    assert ids[0] == CLS_ID
    assert ids[1] != PAD_ID and ids[2] != PAD_ID
    assert all(i == PAD_ID for i in ids[3:])


def test_truncates_long_input():
    long = " ".join(f"w{i}" for i in range(100))
    ids = tok().encode(long)
    assert len(ids) == 32
    assert all(i != PAD_ID for i in ids)


def test_known_answer_matches_rust():
    # Twin of tokenizer::tests::fnv_known_answer.
    t = tok()
    assert t.word_id("hello") == 2 + 0xA430D84680AABD0B % 4094


@settings(max_examples=200, deadline=None)
@given(st.text(max_size=200))
def test_encode_invariants(text):
    t = tok()
    ids = t.encode(text)
    assert len(ids) == 32
    assert ids[0] == CLS_ID
    assert all(0 <= i < 4096 for i in ids)
    # Case-insensitive (ASCII contract only: Rust uses to_ascii_lowercase,
    # so non-ASCII case-folding like 'ß'→'SS' is out of scope).
    if text.isascii():
        assert t.encode(text.upper()) == t.encode(text.lower())
    # Padding is a suffix: no PAD before a non-PAD.
    seen_pad = False
    for i in ids[1:]:
        if i == PAD_ID:
            seen_pad = True
        else:
            assert not seen_pad, "PAD in the middle of a sequence"
