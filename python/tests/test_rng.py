"""Known-answer and statistical tests for the splitmix64 twin.

The reference vectors here are asserted verbatim by the Rust side
(``util::rng::tests``) — together they pin the cross-language contract.
"""

import numpy as np
import pytest

from compile import rng


def test_splitmix_reference_vector():
    out = rng.splitmix64_block(0, 3)
    assert out[0] == 0xE220A8397B1DCDAF
    assert out[1] == 0x6E789E6AA1B965F4
    assert out[2] == 0x06C45D188009454F


def test_fnv_reference_values():
    assert rng.fnv1a64(b"") == 0xCBF29CE484222325
    assert rng.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert rng.fnv1a64(b"foobar") == 0x85944171F73967E8
    assert rng.fnv1a64(b"hello") == 0xA430D84680AABD0B


def test_derive_stable_and_label_sensitive():
    a = rng.derive_seed(42, "layer0.wq")
    b = rng.derive_seed(42, "layer0.wk")
    assert a != b
    assert a == rng.derive_seed(42, "layer0.wq")


def test_uniform53_in_unit_interval():
    bits = rng.splitmix64_block(7, 10_000)
    u = rng.uniform53(bits)
    assert (u >= 0.0).all() and (u < 1.0).all()
    assert abs(u.mean() - 0.5) < 0.01


def test_normal_moments():
    v = rng.normal(7, 200_001, 2.0)  # odd length exercises the tail path
    assert v.dtype == np.float32
    assert abs(v.mean()) < 0.02
    assert abs(v.astype(np.float64).var() - 4.0) < 0.08


def test_normal_prefix_property():
    """Generating n and n+1 values must agree on the shared prefix pair-wise."""
    a = rng.normal(3, 10, 1.0)
    b = rng.normal(3, 12, 1.0)
    np.testing.assert_array_equal(a, b[:10])


@pytest.mark.parametrize("label,shape,std", [("embed", (8, 4), 1.0), ("wq", (2, 3, 3), 0.5)])
def test_normal_tensor_deterministic(label, shape, std):
    t1 = rng.normal_tensor(99, label, shape, std)
    t2 = rng.normal_tensor(99, label, shape, std)
    assert t1.shape == shape
    np.testing.assert_array_equal(t1, t2)
