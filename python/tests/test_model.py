"""L2 model tests: shapes, invariants, pallas-vs-ref, semantic structure."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.model import make_encoder
from compile.tokenizer import Tokenizer
from compile.weights import ModelParams, flat_inputs, generate


# Small geometry for speed; full geometry is covered by test_aot + parity.
P_SMALL = ModelParams(vocab_size=512, dim=96, hidden=192, layers=2, heads=4, seq_len=16)


@pytest.fixture(scope="module")
def small():
    w = generate(P_SMALL)
    tok = Tokenizer(P_SMALL.vocab_size, P_SMALL.seq_len)
    enc_ref = make_encoder(P_SMALL, use_pallas=False)
    enc_pal = make_encoder(P_SMALL, use_pallas=True)
    return w, tok, enc_ref, enc_pal


def encode(enc, w, tok, texts):
    ids = np.array(tok.encode_batch(texts), dtype=np.int64)
    return np.asarray(enc(ids, *flat_inputs(w, P_SMALL))[0])


def test_output_shape_and_norm(small):
    w, tok, enc_ref, _ = small
    e = encode(enc_ref, w, tok, ["hello", "two words here", ""])
    assert e.shape == (3, P_SMALL.dim)
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, rtol=1e-5)
    assert np.isfinite(e).all()


def test_pallas_equals_ref_full_model(small):
    w, tok, enc_ref, enc_pal = small
    texts = ["how do i reset my password", "the quick brown fox", "a", ""]
    e1 = encode(enc_ref, w, tok, texts)
    e2 = encode(enc_pal, w, tok, texts)
    np.testing.assert_allclose(e1, e2, rtol=1e-5, atol=1e-5)


def test_batch_independence(small):
    """Each row depends only on its own text (padding never leaks)."""
    w, tok, enc_ref, _ = small
    alone = encode(enc_ref, w, tok, ["where is my order"])
    batch = encode(enc_ref, w, tok, ["where is my order", "x", "something else"])
    np.testing.assert_allclose(alone[0], batch[0], rtol=1e-5, atol=1e-6)


def test_paraphrase_closer_than_unrelated(small):
    w, tok, enc_ref, _ = small
    e = encode(
        enc_ref, w, tok,
        [
            "how do i reset my password",
            "how can i reset my password",
            "best pasta recipe with tomatoes",
        ],
    )
    near = float(e[0] @ e[1])
    far = float(e[0] @ e[2])
    assert near > far + 0.1, f"near={near} far={far}"


def test_word_order_matters_but_weakly(small):
    w, tok, enc_ref, _ = small
    e = encode(enc_ref, w, tok, ["alpha beta gamma delta", "delta gamma beta alpha"])
    sim = float(e[0] @ e[1])
    assert 0.5 < sim < 0.99999, f"positional signal out of range: {sim}"


@settings(max_examples=10, deadline=None)
@given(st.lists(st.text(alphabet="abcdefgh ", min_size=0, max_size=40), min_size=1, max_size=4))
def test_encoder_total_on_arbitrary_text(small, texts):
    w, tok, enc_ref, _ = small
    e = encode(enc_ref, w, tok, texts)
    assert e.shape == (len(texts), P_SMALL.dim)
    assert np.isfinite(e).all()
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, rtol=1e-4)
