"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ``ref.py`` is the
core correctness signal for the compile path.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.scorer import BLOCK, scores, topk


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def random_mask(rng, b, s):
    """At least one real token per row (CLS is always present)."""
    m = (rng.random((b, s)) < 0.7).astype(np.float32)
    m[:, 0] = 1.0
    return m


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s=st.sampled_from([4, 8, 32]),
    dh=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, b, h, s, dh), rand(rng, b, h, s, dh), rand(rng, b, h, s, dh)
    mask = random_mask(rng, b, s)
    got = np.asarray(attention(q, k, v, mask))
    want = np.asarray(ref.attention_ref(q, k, v, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_fully_masked_keys_ignored():
    rng = np.random.default_rng(0)
    b, h, s, dh = 2, 2, 8, 16
    q, k, v = rand(rng, b, h, s, dh), rand(rng, b, h, s, dh), rand(rng, b, h, s, dh)
    mask = np.zeros((b, s), dtype=np.float32)
    mask[:, :3] = 1.0
    # Perturb the masked-out keys/values: output must not change.
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 3:, :] += 100.0
    v2[:, :, 3:, :] -= 50.0
    a1 = np.asarray(attention(q, k, v, mask))
    a2 = np.asarray(attention(q, k2, v2, mask))
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(1, 4),
    d=st.sampled_from([16, 64, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_scores_match_ref(nb, d, seed):
    rng = np.random.default_rng(seed)
    n = nb * BLOCK
    corpus = rand(rng, n, d)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    q = rand(rng, d)
    q /= np.linalg.norm(q)
    got = np.asarray(scores(q, corpus))
    want = np.asarray(ref.scores_ref(q, corpus))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_scores_rejects_unaligned_n():
    rng = np.random.default_rng(1)
    with pytest.raises(AssertionError):
        scores(rand(rng, 8), rand(rng, BLOCK + 1, 8))


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 5, 16]), seed=st.integers(0, 2**31 - 1))
def test_topk_matches_numpy(k, seed):
    rng = np.random.default_rng(seed)
    n, d = 2 * BLOCK, 32
    corpus = rand(rng, n, d)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    q = rand(rng, d)
    q /= np.linalg.norm(q)
    vals, idx = topk(q, corpus, k)
    vals, idx = np.asarray(vals), np.asarray(idx).astype(np.int64)
    s = corpus @ q
    order = np.argsort(-s)[:k]
    np.testing.assert_array_equal(idx, order)
    np.testing.assert_allclose(vals, s[order], rtol=1e-5, atol=1e-6)
    # Descending.
    assert (np.diff(vals) <= 1e-7).all()
