"""AOT pipeline tests: HLO text is parseable-shaped, manifest is sound.

Full-geometry artifact building is exercised by `make artifacts` + the
Rust parity suite; here we lower a small variant end-to-end to keep the
pytest cycle fast, and sanity-check the shipped manifest when present.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.weights import ModelParams


def test_encoder_lowering_produces_hlo_text():
    p = ModelParams(vocab_size=64, dim=32, hidden=64, layers=1, heads=2, seq_len=8)
    text, spec = aot.lower_encoder(p, batch=2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # The old xla_extension text parser chokes on these newer constructs;
    # they must never appear in our artifacts.
    assert "topk(" not in text
    assert spec["input_shapes"][0] == [2, 8]
    assert spec["output_shapes"] == [[2, 32]]
    # 1 token input + 8 weight tensors.
    assert len(spec["input_shapes"]) == 9


def test_scorer_lowering_produces_hlo_text():
    p = ModelParams(dim=32)
    text, spec = aot.lower_scorer(p, n=256, k=4)
    assert "HloModule" in text
    assert "topk(" not in text, "lax.top_k regression: unparseable on xla 0.5.1"
    assert spec["input_shapes"] == [[32], [256, 32]]
    assert spec["output_shapes"] == [[4], [4]]


def test_build_writes_manifest(tmp_path, monkeypatch):
    # Small + few variants for speed.
    monkeypatch.setattr(aot, "ENCODER_BATCH_SIZES", (1, 2))
    monkeypatch.setattr(aot, "SCORER_SIZES", (256,))
    monkeypatch.setattr(aot, "SCORER_TOPK", 4)
    p = ModelParams(vocab_size=64, dim=32, hidden=64, layers=1, heads=2, seq_len=8)
    manifest = aot.build(str(tmp_path), p)
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"encoder_b1", "encoder_b2", "scorer_n256"}
    for a in manifest["artifacts"]:
        assert os.path.exists(tmp_path / a["file"])
    with open(tmp_path / "manifest.json") as f:
        on_disk = json.load(f)
    assert on_disk["model"]["dim"] == 32
    assert on_disk["model"]["seed"] == p.seed


def test_shipped_manifest_consistent_if_present():
    here = os.path.dirname(__file__)
    art = os.path.normpath(os.path.join(here, "..", "..", "artifacts"))
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        m = json.load(f)
    assert m["model"]["dim"] == ModelParams().dim
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(art, a["file"])), a["file"]


def test_lowered_encoder_executes_like_eager():
    """The lowered+compiled HLO computes the same numbers as eager jax."""
    from compile.model import make_encoder
    from compile.weights import flat_inputs, generate

    p = ModelParams(vocab_size=64, dim=32, hidden=64, layers=1, heads=2, seq_len=8)
    w = generate(p)
    tokens = np.array([[1, 5, 9, 0, 0, 0, 0, 0]], dtype=np.int64)
    enc = make_encoder(p, use_pallas=True)
    eager = np.asarray(enc(tokens, *flat_inputs(w, p))[0])
    compiled = jax.jit(enc).lower(
        jax.ShapeDtypeStruct(tokens.shape, jnp.int64),
        *[jax.ShapeDtypeStruct(x.shape, jnp.float32) for x in flat_inputs(w, p)],
    ).compile()
    out = np.asarray(compiled(tokens, *flat_inputs(w, p))[0])
    np.testing.assert_allclose(eager, out, rtol=1e-6, atol=1e-6)
