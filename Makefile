# GPT Semantic Cache — build/verify entry points.
#
#   make verify      tier-1: fmt + build + tests + doc tests + batched
#                    loopback smoke (paraphrase hit + metrics consistency)
#                    + smoke benches
#   make build       release build of the Rust crate
#   make test        unit + integration tests (incl. tests/batching.rs:
#                    trace-replay parity, 16-thread stress, window-policy
#                    property tests, TTL-under-batching)
#   make chaos       upstream-fault chaos suite (tests/chaos.rs): outage
#                    -> degraded serving -> typed 503, breaker
#                    open/half-open/close over live HTTP, extended
#                    balance under mixed seeded faults
#   make serve       run the semcached HTTP daemon on :8080
#   make bench-batch batch serving throughput baseline (full mode)
#   make bench-http  batched vs unbatched HTTP loopback throughput vs
#                    direct serve_batch, 8 connections (full mode)
#   make bench-embed embedding hot path: arena + parallel encode_batch +
#                    exact-match memo tier, with acceptance floors
#                    (full mode; SEMCACHE_BENCH_ENFORCE=1 gates on them)
#   make bench-persist warm restart (snapshot + WAL recovery) vs cold
#                    re-encode rebuild at 10k entries; floor: warm >= 5x,
#                    replayed-trace hit parity (full mode)
#   make artifacts   lower the JAX/Pallas encoder to HLO (needs python/jax)

.PHONY: verify build test chaos serve bench-batch bench-http bench-embed bench-persist artifacts

verify:
	./rust/verify.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

chaos:
	cd rust && cargo test --test chaos

serve:
	cd rust && cargo run --release --bin semcached -- serve --port 8080 --populate small

bench-batch:
	cd rust && cargo bench --bench bench_batch_throughput

bench-http:
	cd rust && cargo bench --bench bench_http_loopback

bench-embed:
	cd rust && cargo bench --bench bench_embed_throughput

bench-persist:
	cd rust && cargo bench --bench bench_persist_restart

artifacts:
	cd python && python -m compile.aot
