# GPT Semantic Cache — build/verify entry points.
#
#   make verify      tier-1: build + tests + doc tests + smoke bench
#   make build       release build of the Rust crate
#   make test        unit + integration tests
#   make bench-batch batch serving throughput baseline (full mode)
#   make artifacts   lower the JAX/Pallas encoder to HLO (needs python/jax)

.PHONY: verify build test bench-batch artifacts

verify:
	./rust/verify.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench-batch:
	cd rust && cargo bench --bench bench_batch_throughput

artifacts:
	cd python && python -m compile.aot
